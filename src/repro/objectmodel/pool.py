"""Buffer pool with pinning, LRU eviction, and zombie-page tracking
(paper §2, Appendix C).

Page lifetimes during pipelined execution:

* **input pages** — pinned while any vector list derived from them is in
  flight;
* the **live output page** — the active allocation block;
* **zombie output pages** — full pages holding output *and* intermediate
  data: cannot be flushed until the in-flight vector list drains (≤2 per
  pipeline, as proven in the paper);
* **zombie pages** — intermediate-only; flushed (dropped) when the vector
  list completes, never written back.
"""
from __future__ import annotations

import collections
from typing import Callable, Dict, List, Optional

from repro.objectmodel.page import AllocPolicy, Page

__all__ = ["BufferPool", "PageState"]


class PageState:
    INPUT = "input"
    LIVE_OUTPUT = "live_output"
    ZOMBIE_OUTPUT = "zombie_output"  # output + intermediate: pinned, write back later
    ZOMBIE = "zombie"  # intermediate only: pinned, never written back
    CACHED = "cached"  # clean, evictable
    FREE = "free"


class BufferPool:
    """Fixed-frame buffer pool; eviction spills via a user callback."""

    def __init__(self, num_frames: int, page_size: int,
                 spill: Optional[Callable[[Page], None]] = None,
                 fetch: Optional[Callable[[int], Page]] = None):
        self.num_frames = num_frames
        self.page_size = page_size
        self._spill = spill
        self._fetch = fetch
        self._pages: Dict[int, Page] = {}
        self._state: Dict[int, str] = {}
        self._lru: "collections.OrderedDict[int, None]" = collections.OrderedDict()
        self._free: List[Page] = []
        self._next_id = 0
        self.evictions = 0
        self.spills = 0

    # ------------------------------------------------------------ frames
    def _frame(self) -> Page:
        if self._free:
            p = self._free.pop()
            p.reset()
            return p
        if len(self._pages) < self.num_frames:
            p = Page(self._next_id, self.page_size)
            self._next_id += 1
            return p
        victim_id = self._pick_victim()
        victim = self._pages.pop(victim_id)
        self._state.pop(victim_id)
        self._lru.pop(victim_id, None)
        self.evictions += 1
        if self._spill is not None:
            self._spill(victim)
            self.spills += 1
        victim.reset()
        victim.page_id = self._next_id
        self._next_id += 1
        return victim

    def _pick_victim(self) -> int:
        for pid in self._lru:  # oldest first
            if self._state.get(pid) == PageState.CACHED and self._pages[pid].pinned == 0:
                return pid
        raise RuntimeError(
            "buffer pool exhausted: all frames pinned "
            f"({collections.Counter(self._state.values())})")

    # -------------------------------------------------------------- API
    def get_page(self, state: str = PageState.LIVE_OUTPUT) -> Page:
        p = self._frame()
        self._pages[p.page_id] = p
        self._state[p.page_id] = state
        p.pinned = 1
        return p

    def page(self, page_id: int) -> Page:
        p = self._pages.get(page_id)
        if p is None:
            if self._fetch is None:
                raise KeyError(f"page {page_id} not resident and no fetch fn")
            p = self._fetch(page_id)  # page-in from storage (no deserialization)
            self._pages[page_id] = p
            self._state[page_id] = PageState.CACHED
            self._lru[page_id] = None
        self._lru.move_to_end(page_id, last=True) if page_id in self._lru else None
        return p

    def pin(self, page_id: int) -> Page:
        p = self.page(page_id)
        p.pinned += 1
        return p

    def unpin(self, page_id: int) -> None:
        p = self._pages[page_id]
        p.pinned = max(0, p.pinned - 1)
        if p.pinned == 0 and self._state.get(page_id) not in (
                PageState.ZOMBIE, PageState.ZOMBIE_OUTPUT):
            self._state[page_id] = PageState.CACHED
            self._lru[page_id] = None

    def mark(self, page_id: int, state: str) -> None:
        self._state[page_id] = state

    def state_of(self, page_id: int) -> str:
        return self._state[page_id]

    def flush_zombies(self) -> List[int]:
        """Vector list fully drained: zombie-output pages become writable
        output (CACHED); pure zombie pages are recycled."""
        flushed = []
        for pid, st in list(self._state.items()):
            if st == PageState.ZOMBIE_OUTPUT:
                self._state[pid] = PageState.CACHED
                self._pages[pid].pinned = 0
                self._lru[pid] = None
                flushed.append(pid)
            elif st == PageState.ZOMBIE:
                p = self._pages.pop(pid)
                self._state.pop(pid)
                self._lru.pop(pid, None)
                self._free.append(p)
                flushed.append(pid)
        return flushed

    def zombie_output_count(self) -> int:
        return sum(1 for s in self._state.values() if s == PageState.ZOMBIE_OUTPUT)

    @property
    def resident(self) -> int:
        return len(self._pages)
