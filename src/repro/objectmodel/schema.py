"""Declarative typed record schemas — the "in the small" object model as a
typed front-end (paper §4, §6.3).

A :class:`Record` subclass declares a packed record layout field by field::

    class Order(Record):
        okey:  i64
        price: f64
        name:  S(16)
        parts: vector(i8, 32)

The metaclass compiles the annotations into a numpy structured dtype,
registers the type with the catalog (:data:`~repro.objectmodel.handle
.GLOBAL_TYPES` — the paper's ".so shipping" analogue), and records the
class in a schema registry so the engine can resolve column accesses
against it *at graph-build time*: a typo'd field on a typed dataset raises
:class:`~repro.core.lambdas.UnknownColumnError` naming the schema's fields
before anything executes, instead of failing deep inside a kernel.

The schema class is the canonical type argument everywhere a type name was
accepted before — ``session.create_set(Order)``, ``session.load(...,
Order)``, ``session.read(..., Order)``, ``ScanSet(db, set, Order)`` — and
plain string type names keep working for untyped sets.

:func:`record` builds a schema dynamically (shapes known only at runtime,
e.g. a per-dataset vector width); :func:`pair_schema` synthesizes the
record-pair schema of a join, which is what makes ``join(project=None)``
possible.
"""
from __future__ import annotations

import hashlib
import sys
from typing import Dict, Mapping, Optional, Tuple, Union

import numpy as np

from repro.objectmodel.handle import GLOBAL_TYPES

__all__ = [
    "Field", "Record", "RecordMeta", "record", "schema_for", "pair_schema",
    "pair_field_map", "group_schema",
    "i8", "i16", "i32", "i64", "u8", "u16", "u32", "u64",
    "f32", "f64", "boolean", "S", "U", "vector",
]


class Field:
    """One typed field: a numpy scalar dtype plus an optional inner shape."""

    __slots__ = ("dtype", "shape")

    def __init__(self, dtype, shape: Tuple[int, ...] = ()):
        self.dtype = np.dtype(dtype)
        self.shape = tuple(int(s) for s in shape)

    def descr(self):
        return (self.dtype, self.shape) if self.shape else self.dtype

    def __repr__(self):
        if self.shape:
            return f"{self.dtype.name}{list(self.shape)}"
        return self.dtype.name


i8, i16, i32, i64 = (Field(t) for t in (np.int8, np.int16, np.int32,
                                        np.int64))
u8, u16, u32, u64 = (Field(t) for t in (np.uint8, np.uint16, np.uint32,
                                        np.uint64))
f32, f64 = Field(np.float32), Field(np.float64)
boolean = Field(np.bool_)


def S(n: int) -> Field:
    """Fixed-width byte string (``S8`` etc.)."""
    return Field(f"S{int(n)}")


def U(n: int) -> Field:
    """Fixed-width unicode string."""
    return Field(f"U{int(n)}")


def vector(base: Union[Field, np.dtype, type], *shape) -> Field:
    """A shaped field: ``vector(f64, 3)`` or ``vector(i8, (4, 4))``."""
    if len(shape) == 1 and isinstance(shape[0], (tuple, list)):
        shape = tuple(shape[0])
    base_dt = base.dtype if isinstance(base, Field) else np.dtype(base)
    return Field(base_dt, tuple(shape))


# python scalar annotations accepted as sugar
_PY_SUGAR = {int: i64, float: f64, bool: boolean}

# type_name -> Record subclass (the schema registry; GLOBAL_TYPES holds the
# dtype side, this holds the class with field metadata)
_SCHEMAS: Dict[str, type] = {}


def _as_field(ann, owner: str, fname: str) -> Field:
    if isinstance(ann, Field):
        return ann
    if ann in _PY_SUGAR:
        return _PY_SUGAR[ann]
    try:
        return Field(ann)
    except TypeError:
        raise TypeError(
            f"{owner}.{fname}: cannot interpret annotation {ann!r} as a "
            "field type (use i64/f64/S(n)/vector(...) or a numpy dtype)")


def _resolve_annotations(ns: Mapping, module: str) -> Dict[str, object]:
    """Annotation values, evaluating postponed (string) annotations against
    the defining module's globals plus this module's field vocabulary."""
    ann = ns.get("__annotations__", {})
    out = {}
    mod_ns = getattr(sys.modules.get(module), "__dict__", {})
    for k, v in ann.items():
        if isinstance(v, str):
            v = eval(v, {**globals(), **mod_ns})  # noqa: S307 — schema DSL
        out[k] = v
    return out


class RecordMeta(type):
    def __new__(mcs, name, bases, ns, **kw):
        cls = super().__new__(mcs, name, bases, ns, **kw)
        if ns.get("_abstract", False):
            return cls
        fields: Dict[str, Field] = {}
        for fname, ann in _resolve_annotations(ns, ns.get("__module__",
                                                          "")).items():
            if fname.startswith("_"):
                raise ValueError(
                    f"{name}.{fname}: field names may not start with '_' "
                    "(reserved for the engine)")
            fields[fname] = _as_field(ann, name, fname)
        if not fields:
            raise ValueError(f"Record schema {name!r} declares no fields")
        type_name = ns.get("__type_name__") or name
        dtype = np.dtype([(f, ft.descr()) for f, ft in fields.items()])
        prior = _SCHEMAS.get(type_name)
        if prior is not None and prior.dtype != dtype:
            raise ValueError(
                f"schema {type_name!r} is already registered with a "
                f"different layout ({prior.dtype} vs {dtype})")
        cls.type_name = type_name
        cls.dtype = dtype
        cls.fields = tuple(fields)
        cls.field_set = frozenset(fields)
        cls.field_types = dict(fields)
        cls.type_code = GLOBAL_TYPES.register(type_name, dtype)
        _SCHEMAS[type_name] = cls
        return cls


class Record(metaclass=RecordMeta):
    """Base class for typed record schemas. Subclass with annotated fields;
    never instantiated — records live as packed numpy structured arrays."""

    _abstract = True
    # populated by the metaclass on concrete subclasses
    type_name: str
    dtype: np.dtype
    fields: Tuple[str, ...]
    field_set: frozenset
    field_types: Dict[str, Field]
    type_code: int

    def __init__(self):
        raise TypeError(
            f"{type(self).__name__} is a schema, not a container — build "
            f"packed records with {type(self).__name__}.empty(n) or .pack()")

    # ------------------------------------------------------- constructors
    @classmethod
    def empty(cls, n: int) -> np.ndarray:
        """``n`` zeroed packed records of this schema."""
        return np.zeros(int(n), cls.dtype)

    @classmethod
    def pack(cls, **columns) -> np.ndarray:
        """Pack named columns (one array-like per field) into records."""
        missing = cls.field_set - set(columns)
        extra = set(columns) - cls.field_set
        if missing or extra:
            raise ValueError(
                f"{cls.type_name}.pack(): "
                + (f"missing fields {sorted(missing)} " if missing else "")
                + (f"unknown fields {sorted(extra)} " if extra else "")
                + f"(schema fields: {list(cls.fields)})")
        n = len(np.asarray(columns[cls.fields[0]]))
        out = np.zeros(n, cls.dtype)
        for f in cls.fields:
            out[f] = columns[f]
        return out

    @classmethod
    def validate(cls, records: np.ndarray) -> np.ndarray:
        """Check a packed array against this schema (exact layout match)."""
        records = np.asarray(records)
        if records.dtype != cls.dtype:
            raise TypeError(
                f"records have dtype {records.dtype}, but schema "
                f"{cls.type_name!r} is {cls.dtype} — repack with "
                f"{cls.type_name}.pack(...) or fix the schema")
        return records

    @classmethod
    def describe(cls) -> str:
        body = "; ".join(f"{f}: {ft!r}" for f, ft in cls.field_types.items())
        return f"{cls.type_name}({body})"


def record(type_name: str, fields: Optional[Mapping[str, object]] = None,
           **kw_fields) -> type:
    """Build a schema dynamically: ``record("Point", x=vector(f64, dim))``.

    Re-declaring an identical layout under the same name returns the
    existing class (so helpers can call this per-use without churning the
    catalog); a conflicting layout raises.
    """
    spec = dict(fields or {}, **kw_fields)
    prior = _SCHEMAS.get(type_name)
    if prior is not None:
        candidate = np.dtype([(f, _as_field(a, type_name, f).descr())
                              for f, a in spec.items()])
        if prior.dtype == candidate:
            return prior
        raise ValueError(
            f"schema {type_name!r} is already registered with a different "
            f"layout ({prior.dtype} vs {candidate})")
    ns = {"__annotations__": dict(spec), "__module__": __name__,
          "__type_name__": type_name}
    return RecordMeta(type_name, (Record,), ns)


def schema_for(type_name) -> Optional[type]:
    """The registered schema class for a type name (or the class itself)."""
    if isinstance(type_name, type) and issubclass(type_name, Record):
        return type_name
    return _SCHEMAS.get(type_name)


def pair_field_map(left: type, right: type) -> Tuple[Tuple[str, int, str],
                                                     ...]:
    """The field mapping of ``left JOIN right`` as ``(dst, side, src)``
    triples (side 0 = left, 1 = right). Left fields keep their names; a
    right field colliding with a left one is prefixed with the right
    schema's (lowercased) type name. Single source of truth for both the
    pair dtype (:func:`pair_schema`) and the default join projection."""
    moves = [(f, 0, f) for f in left.fields]
    taken = set(left.fields)
    for f in right.fields:
        dst = f if f not in taken else f"{right.type_name.lower()}_{f}"
        if dst in taken:
            raise ValueError(
                f"pair schema {left.type_name}×{right.type_name}: cannot "
                f"disambiguate field {f!r} (both sides define "
                f"{dst!r} too) — pass an explicit project=")
        taken.add(dst)
        moves.append((dst, 1, f))
    return tuple(moves)


def group_schema(fields: Mapping[str, Field]) -> type:
    """Synthesize the record schema of a grouped-aggregation result (key
    fields followed by the named aggregate fields, in output order). The
    type name is derived deterministically from the field layout, so two
    structurally identical ``group_by().agg()`` queries share one schema
    class (``record()`` dedupes identical re-declarations) and repeated
    compilation never churns the catalog."""
    desc = ";".join(f"{n}:{f.dtype.str}{f.shape}" for n, f in fields.items())
    name = "Group_" + hashlib.md5(desc.encode()).hexdigest()[:10]
    return record(name, dict(fields))


def pair_schema(left: type, right: type) -> type:
    """The synthesized record-pair schema of ``left JOIN right`` (field
    layout per :func:`pair_field_map`) — the default ``join()``
    projection's output type."""
    sides = (left.field_types, right.field_types)
    fields = {dst: sides[side][src]
              for dst, side, src in pair_field_map(left, right)}
    return record(f"Pair_{left.type_name}_{right.type_name}", fields)
