"""Page-as-a-heap allocation (paper §3, §6.4, Appendix B).

A :class:`Page` is a fixed-size contiguous buffer. All object allocation is
*in-place* on the active page via bump allocation; the occupied prefix of a
page can be moved across processes / to disk / onto a device **byte-for-byte**
with zero (de)serialization — PlinyCompute's "zero-cost data movement".

Three allocation policies (paper Appendix B):

* ``LIGHTWEIGHT_REUSE`` (default) — freed space goes into log2 size-class
  buckets and is scanned before bump-allocating fresh space.
* ``NO_REUSE`` — pure region allocation; frees are no-ops (fastest, may waste).
* ``RECYCLE`` — layered on lightweight-reuse: fixed-size objects of the same
  type are kept on a per-type free list and handed back verbatim.
"""
from __future__ import annotations

import enum
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["AllocPolicy", "Page", "PageAllocator", "OutOfPageMemory"]

DEFAULT_PAGE_SIZE = 1 << 20  # 1 MiB default allocation block (paper's example)
_ALIGN = 8


class AllocPolicy(enum.Enum):
    LIGHTWEIGHT_REUSE = "lightweight_reuse"
    NO_REUSE = "no_reuse"
    RECYCLE = "recycle"


class OutOfPageMemory(Exception):
    """Raised when the active allocation block is full (paper: the execution
    engine catches this and rolls a fresh page in)."""


def _bucket(nbytes: int) -> int:
    return max(0, int(math.ceil(math.log2(max(1, nbytes)))))


class Page:
    """A fixed-size allocation block backed by a single numpy byte buffer."""

    __slots__ = (
        "page_id",
        "size",
        "buf",
        "policy",
        "_bump",
        "_buckets",
        "_recycle",
        "live_objects",
        "refcounts",
        "pinned",
        "freed_bytes",
    )

    def __init__(self, page_id: int, size: int = DEFAULT_PAGE_SIZE,
                 policy: AllocPolicy = AllocPolicy.LIGHTWEIGHT_REUSE,
                 buf: Optional[np.ndarray] = None):
        if buf is not None and buf.nbytes != size:
            raise ValueError(f"backing buffer is {buf.nbytes} B, expected {size}")
        self.page_id = page_id
        self.size = size
        self.buf = buf if buf is not None else np.zeros(size, dtype=np.uint8)
        self.policy = policy
        self._bump = 0
        self._buckets: Dict[int, List[Tuple[int, int]]] = {}
        self._recycle: Dict[Tuple[str, int], List[int]] = {}
        self.live_objects = 0
        self.refcounts: Dict[int, int] = {}
        self.pinned = 0
        self.freed_bytes = 0

    # ------------------------------------------------------------- alloc
    def alloc(self, nbytes: int, type_key: Optional[str] = None) -> int:
        """Allocate ``nbytes`` on this page; returns the byte offset."""
        nbytes = max(1, nbytes)
        if self.policy is AllocPolicy.RECYCLE and type_key is not None:
            lst = self._recycle.get((type_key, nbytes))
            if lst:
                off = lst.pop()
                self.live_objects += 1
                self.refcounts[off] = 1
                return off
        if self.policy in (AllocPolicy.LIGHTWEIGHT_REUSE, AllocPolicy.RECYCLE):
            b = _bucket(nbytes)
            lst = self._buckets.get(b)
            if lst:
                for i, (off, sz) in enumerate(lst):
                    if sz >= nbytes:
                        lst.pop(i)
                        self.freed_bytes -= sz
                        self.live_objects += 1
                        self.refcounts[off] = 1
                        return off
        off = (self._bump + _ALIGN - 1) // _ALIGN * _ALIGN
        if off + nbytes > self.size:
            raise OutOfPageMemory(
                f"page {self.page_id}: need {nbytes} B at {off}, size {self.size}")
        self._bump = off + nbytes
        self.live_objects += 1
        self.refcounts[off] = 1
        return off

    def free(self, offset: int, nbytes: int, type_key: Optional[str] = None) -> None:
        """Deallocate (meaning depends on the page policy)."""
        if offset in self.refcounts:
            del self.refcounts[offset]
        self.live_objects = max(0, self.live_objects - 1)
        if self.policy is AllocPolicy.NO_REUSE:
            self.freed_bytes += nbytes
            return
        if self.policy is AllocPolicy.RECYCLE and type_key is not None:
            self._recycle.setdefault((type_key, nbytes), []).append(offset)
            return
        self._buckets.setdefault(_bucket(nbytes), []).append((offset, nbytes))
        self.freed_bytes += nbytes

    # ----------------------------------------------------------- refcount
    def incref(self, offset: int) -> None:
        if offset in self.refcounts:  # un-refcounted objects are skipped
            self.refcounts[offset] += 1

    def decref(self, offset: int, nbytes: int, type_key: Optional[str] = None) -> bool:
        """Returns True if the object was deallocated by this decref."""
        c = self.refcounts.get(offset)
        if c is None:
            return False
        if c <= 1:
            self.free(offset, nbytes, type_key)
            return True
        self.refcounts[offset] = c - 1
        return False

    def disable_refcount(self, offset: int) -> None:
        """ObjectPolicy::noRefCount — region semantics for this object."""
        self.refcounts.pop(offset, None)

    # --------------------------------------------------------------- view
    def view(self, offset: int, dtype: np.dtype, count: int = 1) -> np.ndarray:
        """Zero-copy typed view of page memory (the Handle dereference)."""
        dt = np.dtype(dtype)
        end = offset + dt.itemsize * count
        if end > self.size:
            raise IndexError(f"view [{offset}:{end}) outside page of {self.size} B")
        return self.buf[offset:end].view(dt)

    # ----------------------------------------------------------- movement
    def occupied_bytes(self) -> int:
        return self._bump

    def payload(self) -> np.ndarray:
        """The occupied prefix — what gets shipped, verbatim (zero-copy)."""
        return self.buf[: self._bump]

    @classmethod
    def from_payload(cls, page_id: int, payload: np.ndarray, size: int,
                     policy: AllocPolicy = AllocPolicy.LIGHTWEIGHT_REUSE) -> "Page":
        """Reconstruct a page at a receiving 'process' — no deserialization,
        the payload bytes are adopted as-is and offsets remain valid. When
        the payload already spans the full page (the wire-transfer case),
        its buffer is adopted without even a copy."""
        payload = payload.view(np.uint8)
        if payload.nbytes == size and payload.flags["C_CONTIGUOUS"]:
            buf = payload
        else:
            buf = np.zeros(size, dtype=np.uint8)
            buf[: payload.nbytes] = payload
        p = cls(page_id, size, policy, buf=buf)
        p._bump = int(payload.nbytes)
        return p

    @property
    def utilization(self) -> float:
        used = self._bump - self.freed_bytes
        return used / self.size if self.size else 0.0

    def reset(self) -> None:
        """Recycle the whole page as a fresh region (buffer-pool reuse)."""
        self._bump = 0
        self._buckets.clear()
        self._recycle.clear()
        self.refcounts.clear()
        self.live_objects = 0
        self.freed_bytes = 0


class PageAllocator:
    """Per-'thread' allocator: one *active* block plus inactive managed blocks
    (paper §6.4). ``make_block()`` is ``makeObjectAllocatorBlock()``."""

    def __init__(self, page_size: int = DEFAULT_PAGE_SIZE,
                 policy: AllocPolicy = AllocPolicy.LIGHTWEIGHT_REUSE):
        self.page_size = page_size
        self.policy = policy
        self._next_id = 0
        self.active: Optional[Page] = None
        self.inactive: Dict[int, Page] = {}
        self.reclaimed: List[int] = []  # ids of auto-deallocated blocks

    def make_block(self, size: Optional[int] = None,
                   policy: Optional[AllocPolicy] = None) -> Page:
        prev = self.active
        if prev is not None:
            if prev.live_objects > 0:
                self.inactive[prev.page_id] = prev  # becomes inactive, managed
            else:
                self.reclaimed.append(prev.page_id)
        page = Page(self._next_id, size or self.page_size, policy or self.policy)
        self._next_id += 1
        self.active = page
        return page

    def adopt(self, page: Page) -> None:
        """Register an inactive *un-managed* block (e.g. arrived off the wire)."""
        self.inactive[page.page_id] = page

    def page(self, page_id: int) -> Page:
        if self.active is not None and self.active.page_id == page_id:
            return self.active
        return self.inactive[page_id]

    def note_unreachable(self, page: Page) -> None:
        """Called when a managed block's live-object count hits zero."""
        if page.live_objects == 0 and page.page_id in self.inactive:
            del self.inactive[page.page_id]
            self.reclaimed.append(page.page_id)
