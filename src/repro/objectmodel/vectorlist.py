"""Vector lists — the unit of vectorized execution (paper §5.2).

A :class:`VectorList` is an ordered set of named, equal-length columns
(numpy on host, jax.Array inside jitted stages). Pipeline stages consume a
vector list and emit a new one that *shallow-copies* surviving columns and
appends freshly computed ones — exactly the paper's TCAP ``APPLY`` contract.
"""
from __future__ import annotations

from typing import Dict, Iterable, Iterator, List, Mapping, Sequence, Tuple

import numpy as np

__all__ = ["VectorList"]


class VectorList:
    def __init__(self, columns: Mapping[str, np.ndarray] | None = None):
        self._cols: Dict[str, np.ndarray] = {}
        if columns:
            for k, v in columns.items():
                self.append(k, v)

    # ------------------------------------------------------------ basics
    def append(self, name: str, col) -> "VectorList":
        n = self.num_rows
        ln = col.shape[0] if hasattr(col, "shape") else len(col)
        if n is not None and ln != n:
            raise ValueError(
                f"column {name!r} has {ln} rows, vector list has {n}")
        self._cols[name] = col
        return self

    @property
    def num_rows(self):
        for v in self._cols.values():
            return v.shape[0] if hasattr(v, "shape") else len(v)
        return None

    @property
    def names(self) -> List[str]:
        return list(self._cols)

    def __contains__(self, name: str) -> bool:
        return name in self._cols

    def __getitem__(self, name: str):
        return self._cols[name]

    def __len__(self) -> int:
        return len(self._cols)

    def items(self) -> Iterator[Tuple[str, np.ndarray]]:
        return iter(self._cols.items())

    # --------------------------------------------------------- TCAP ops
    def project(self, names: Sequence[str]) -> "VectorList":
        """Shallow-copy the named columns into a new vector list."""
        out = VectorList()
        for n in names:
            out._cols[n] = self._cols[n]  # shallow — no data movement
        return out

    def extended(self, keep: Sequence[str], new_name: str, new_col) -> "VectorList":
        """The APPLY contract: keep columns (shallow) + append one new column."""
        out = self.project(keep)
        out.append(new_name, new_col)
        return out

    def filtered(self, mask, keep: Sequence[str]) -> "VectorList":
        """The FILTER contract: row-select the kept columns by a bool vector."""
        out = VectorList()
        for n in keep:
            out._cols[n] = self._cols[n][mask]
        return out

    def concat(self, other: "VectorList") -> "VectorList":
        out = VectorList()
        for n in self.names:
            out._cols[n] = np.concatenate([self._cols[n], other._cols[n]])
        return out

    def __repr__(self) -> str:
        cols = ", ".join(f"{k}:{tuple(v.shape) if hasattr(v,'shape') else len(v)}"
                         for k, v in self._cols.items())
        return f"VectorList({cols})"
