"""Offset-based Handles and the type catalog (paper §6.2, §6.3).

A :class:`Handle` stores ``(page_id, offset, type_code)`` — never a raw
address — so it survives movement of its page across processes. The
:class:`TypeRegistry` is the catalog-manager analogue: it maps type codes to
numpy dtypes (our "vTable lookup"); *simple* types encode their byte size and
need only a memmove, mirroring the paper's type-code bit split.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple, Union

import numpy as np

from repro.objectmodel.page import Page, PageAllocator

__all__ = ["Handle", "TypeRegistry", "make_object", "make_vector", "deref",
           "NULL_HANDLE", "HANDLE_DTYPE"]

# Wire format of a Handle when embedded inside page memory: 3x int64
# (page_id, offset, type_code) — offset pointers, process-relocatable.
HANDLE_DTYPE = np.dtype([("page", np.int64), ("offset", np.int64),
                         ("code", np.int64)])

_SIMPLE_BIT = 1 << 62  # high bit marks a simple (memmove-able) type


@dataclass(frozen=True)
class Handle:
    page: int
    offset: int
    code: int

    @property
    def is_null(self) -> bool:
        return self.page < 0

    def pack(self) -> np.ndarray:
        out = np.zeros(1, dtype=HANDLE_DTYPE)
        out[0] = (self.page, self.offset, self.code)
        return out

    @classmethod
    def unpack(cls, raw: np.ndarray) -> "Handle":
        r = raw.view(HANDLE_DTYPE)[0]
        return cls(int(r["page"]), int(r["offset"]), int(r["code"]))


NULL_HANDLE = Handle(-1, -1, -1)


class TypeRegistry:
    """Catalog of object types. ``register`` ships the ".so" (here: a dtype)."""

    def __init__(self) -> None:
        self._by_name: Dict[str, int] = {}
        self._dtypes: Dict[int, np.dtype] = {}
        self._names: Dict[int, str] = {}
        self._next = 1
        self.remote_fetches = 0  # catalog round-trips (for tests/benchmarks)

    def register(self, name: str, dtype: np.dtype, simple: bool = False) -> int:
        if name in self._by_name:
            return self._by_name[name]
        dt = np.dtype(dtype)
        code = self._next | (_SIMPLE_BIT if simple else 0)
        self._next += 1
        self._by_name[name] = code
        self._dtypes[code] = dt
        self._names[code] = name
        return code

    def dtype_of(self, code: int) -> np.dtype:
        return self._dtypes[code]

    def name_of(self, code: int) -> str:
        return self._names[code]

    def is_simple(self, code: int) -> bool:
        return bool(code & _SIMPLE_BIT)

    def lookup_or_fetch(self, code: int, remote: "TypeRegistry") -> np.dtype:
        """Local vTable lookup; on miss, fetch the definition from the master
        catalog (paper §6.3's .so shipping), then cache it."""
        if code in self._dtypes:
            return self._dtypes[code]
        self.remote_fetches += 1
        dt = remote.dtype_of(code)
        self._dtypes[code] = dt
        self._names[code] = remote.name_of(code)
        return dt


GLOBAL_TYPES = TypeRegistry()


def make_object(alloc: PageAllocator, code: int, value,
                registry: TypeRegistry = GLOBAL_TYPES,
                refcounted: bool = True) -> Handle:
    """``makeObject<T>()`` — in-place allocation on the active block."""
    page = alloc.active
    if page is None:
        raise RuntimeError("no active allocation block; call make_block() first")
    dt = registry.dtype_of(code)
    off = page.alloc(dt.itemsize, type_key=registry.name_of(code))
    page.view(off, dt, 1)[0] = value
    if not refcounted:
        page.disable_refcount(off)
    return Handle(page.page_id, off, code)


def make_vector(alloc: PageAllocator, code: int, values: Sequence,
                registry: TypeRegistry = GLOBAL_TYPES) -> Tuple[Handle, int]:
    """Allocate a contiguous Vector<T> in-place; returns (handle, count)."""
    page = alloc.active
    if page is None:
        raise RuntimeError("no active allocation block")
    dt = registry.dtype_of(code)
    n = len(values)
    off = page.alloc(dt.itemsize * max(1, n))
    v = page.view(off, dt, n)
    for i, x in enumerate(values):
        v[i] = x
    return Handle(page.page_id, off, code), n


def deref(alloc: PageAllocator, h: Handle, count: int = 1,
          registry: TypeRegistry = GLOBAL_TYPES) -> np.ndarray:
    """Dereference a Handle — a zero-copy typed view into its page."""
    if h.is_null:
        raise ValueError("null Handle dereference")
    page = alloc.page(h.page)
    return page.view(h.offset, registry.dtype_of(h.code), count)


def deep_copy(alloc: PageAllocator, h: Handle, count: int = 1,
              registry: TypeRegistry = GLOBAL_TYPES) -> Handle:
    """Cross-block assignment rule (paper §6.4): assigning a Handle that would
    point outside the active block deep-copies the target into it."""
    page = alloc.active
    assert page is not None
    if h.page == page.page_id:
        page.incref(h.offset)
        return h
    src = deref(alloc, h, count, registry)
    dt = registry.dtype_of(h.code)
    off = page.alloc(dt.itemsize * max(1, count), type_key=registry.name_of(h.code))
    page.view(off, dt, count)[:] = src
    return Handle(page.page_id, off, h.code)
