"""The PC object model, adapted (paper §3, §6; DESIGN.md §2).

Host side: page-as-a-heap allocation, offset Handles, buffer pool, paged
record stores, vector lists. Device side: the paged KV cache — HBM pages +
block-table Handles with free-list recycling.
"""
from repro.objectmodel.page import (AllocPolicy, OutOfPageMemory, Page,
                                    PageAllocator, DEFAULT_PAGE_SIZE)
from repro.objectmodel.handle import (GLOBAL_TYPES, HANDLE_DTYPE, NULL_HANDLE,
                                      Handle, TypeRegistry, deep_copy, deref,
                                      make_object, make_vector)
from repro.objectmodel.vectorlist import VectorList
from repro.objectmodel.pool import BufferPool, PageState
from repro.objectmodel.store import PagedSet, PagedStore
from repro.objectmodel.schema import (Field, Record, boolean, f32, f64, i8,
                                      i16, i32, i64, pair_schema, record,
                                      schema_for, u8, u16, u32, u64, vector,
                                      S, U)
from repro.objectmodel.kvcache import (DenseKVCache, KVCacheConfig,
                                       KVPageManager, PagedKVState,
                                       dense_append, gather_paged_kv,
                                       init_dense_cache, init_paged_state,
                                       paged_append)

__all__ = [
    "AllocPolicy", "OutOfPageMemory", "Page", "PageAllocator",
    "DEFAULT_PAGE_SIZE", "GLOBAL_TYPES", "HANDLE_DTYPE", "NULL_HANDLE",
    "Handle", "TypeRegistry", "deep_copy", "deref", "make_object",
    "make_vector", "VectorList", "BufferPool", "PageState", "PagedSet",
    "PagedStore", "DenseKVCache", "KVCacheConfig", "KVPageManager",
    "PagedKVState", "dense_append", "gather_paged_kv", "init_dense_cache",
    "init_paged_state", "paged_append",
    "Field", "Record", "record", "schema_for", "pair_schema",
    "i8", "i16", "i32", "i64", "u8", "u16", "u32", "u64",
    "f32", "f64", "boolean", "S", "U", "vector",
]
