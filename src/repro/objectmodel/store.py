"""PagedStore — datasets as sets of pages (paper's distributed storage
manager, single-host realization with per-shard page lists).

A dataset is a named list of pages of packed records (one numpy structured
dtype per set). Scans hand out whole pages (zero-copy) which the executor
turns into vector lists. Spill/restore is a raw byte dump of the occupied
prefix — the on-disk format *is* the in-memory format.
"""
from __future__ import annotations

import os
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.objectmodel.page import DEFAULT_PAGE_SIZE, AllocPolicy, Page

__all__ = ["PagedSet", "PagedStore"]


class PagedSet:
    """One stored dataset: a record dtype + the pages holding its records."""

    def __init__(self, name: str, dtype: np.dtype, page_size: int):
        self.name = name
        self.dtype = np.dtype(dtype)
        self.page_size = page_size
        self.pages: List[Page] = []
        self.counts: List[int] = []  # records per page

    @property
    def num_records(self) -> int:
        return sum(self.counts)

    def append_records(self, records: np.ndarray) -> None:
        """Pack records onto pages, filling the last partial page first."""
        records = np.ascontiguousarray(records, dtype=self.dtype)
        per_page = max(1, self.page_size // self.dtype.itemsize)
        i = 0
        while i < len(records):
            if not self.pages or self.counts[-1] >= per_page:
                self.pages.append(Page(len(self.pages), self.page_size,
                                       AllocPolicy.NO_REUSE))
                self.counts.append(0)
            page, cnt = self.pages[-1], self.counts[-1]
            take = min(per_page - cnt, len(records) - i)
            off = page.alloc(self.dtype.itemsize * take)
            page.view(off, self.dtype, take)[:] = records[i:i + take]
            self.counts[-1] += take
            i += take

    def scan(self) -> Iterator[np.ndarray]:
        """Yield each page's records as a zero-copy typed view."""
        for page, cnt in zip(self.pages, self.counts):
            yield page.view(0, self.dtype, cnt)

    def all_records(self) -> np.ndarray:
        chunks = list(self.scan())
        if not chunks:
            return np.empty(0, dtype=self.dtype)
        return np.concatenate(chunks)

    # ----------------------------------------------------- wire movement
    def to_payloads(self) -> List[Tuple[int, np.ndarray]]:
        """Spill-to-memory: each page's occupied prefix verbatim, as
        ``(record_count, payload_bytes)`` pairs. This *is* the wire format
        of the distributed exchange layer — the same byte dump
        :meth:`PagedStore.spill` writes to disk, minus the filesystem."""
        return [(cnt, page.payload())
                for page, cnt in zip(self.pages, self.counts)]

    @classmethod
    def from_payloads(cls, name: str, dtype: np.dtype,
                      payloads: Sequence[Tuple[int, np.ndarray]],
                      page_size: int = DEFAULT_PAGE_SIZE) -> "PagedSet":
        """Restore-from-memory: adopt received page bytes with no parsing
        (the counterpart of :meth:`PagedStore.restore` for wire transfers).
        Each payload buffer is adopted in place — offsets stay valid."""
        s = cls(name, dtype, page_size)
        for i, (cnt, raw) in enumerate(payloads):
            s.pages.append(Page.from_payload(i, raw, raw.nbytes,
                                             AllocPolicy.NO_REUSE))
            s.counts.append(cnt)
        return s


class PagedStore:
    """Named sets + spill-to-disk. Directory layout: <root>/<set>/<page>.bin"""

    def __init__(self, root: Optional[str] = None,
                 page_size: int = DEFAULT_PAGE_SIZE):
        self.root = root
        self.page_size = page_size
        self.sets: Dict[str, PagedSet] = {}
        # names handed out (e.g. by Session.fresh_set_name) but not yet
        # backed by pages — shared here so sessions sharing this store
        # cannot both claim the same name before either writes.
        self.reserved_names: set = set()
        # bumped whenever catalog statistics change (sets created, records
        # appended, spills restored) — physical plans derived from these
        # statistics are cached against this counter and re-derived when it
        # moves. Direct PagedSet.append_records calls bypass it; all engine
        # writes go through send_data.
        self.stats_version = 0
        # per-set version counters (bumped with stats_version, but only
        # for the set that actually changed) — the shard catalog and the
        # warm `--serve` SETUP path key shard reuse on these, so a write
        # to one set never invalidates every other set's resident shards.
        self.set_versions: Dict[str, int] = {}

    def set_version(self, name: str) -> int:
        """The named set's change counter (0 if the set does not exist)."""
        return self.set_versions.get(name, 0)

    def _bump(self, name: str) -> None:
        self.stats_version += 1
        self.set_versions[name] = self.set_versions.get(name, 0) + 1

    def create_set(self, name: str, dtype: np.dtype,
                   page_size: Optional[int] = None) -> PagedSet:
        if name in self.sets:
            raise KeyError(f"set {name!r} exists")
        s = PagedSet(name, dtype, page_size or self.page_size)
        self.sets[name] = s
        self._bump(name)
        return s

    def get_set(self, name: str) -> PagedSet:
        return self.sets[name]

    def send_data(self, name: str, records: np.ndarray,
                  dtype: Optional[np.dtype] = None) -> PagedSet:
        """``sendData()`` — zero-pre-processing dispatch of packed records."""
        s = self.sets.get(name) or self.create_set(
            name, dtype if dtype is not None else records.dtype)
        s.append_records(records)
        self._bump(name)
        return s

    # ------------------------------------------------------------- spill
    def spill(self, name: str) -> int:
        """Write every page's occupied prefix verbatim; returns bytes written."""
        assert self.root, "store has no backing directory"
        s = self.sets[name]
        d = os.path.join(self.root, name)
        os.makedirs(d, exist_ok=True)
        total = 0
        meta = [str(s.dtype.descr if s.dtype.names else s.dtype.str)]
        for i, (page, cnt) in enumerate(zip(s.pages, s.counts)):
            payload = page.payload()
            with open(os.path.join(d, f"{i}.bin"), "wb") as f:
                f.write(payload.tobytes())
            meta.append(f"{i},{cnt},{payload.nbytes}")
            total += payload.nbytes
        with open(os.path.join(d, "META"), "w") as f:
            f.write("\n".join(meta))
        return total

    def restore(self, name: str, dtype: np.dtype) -> PagedSet:
        """Adopt spilled bytes as pages — no parsing, offsets stay valid."""
        assert self.root, "store has no backing directory"
        d = os.path.join(self.root, name)
        with open(os.path.join(d, "META")) as f:
            lines = f.read().splitlines()
        s = PagedSet(name, dtype, self.page_size)
        for line in lines[1:]:
            i, cnt, nbytes = (int(x) for x in line.split(","))
            raw = np.fromfile(os.path.join(d, f"{i}.bin"), dtype=np.uint8,
                              count=nbytes)
            s.pages.append(Page.from_payload(i, raw, self.page_size))
            s.counts.append(cnt)
        self.sets[name] = s
        self._bump(name)
        return s
