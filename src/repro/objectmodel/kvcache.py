"""Device-side page-as-a-heap: the paged KV cache.

This is the TPU-native realization of the PC object model (DESIGN.md §2):
HBM is the buffer pool, KV pages are fixed-size allocation blocks, and block
tables are vectors of offset Handles. Pages are recycled through a free list
(the *recycling* allocation policy) — never compacted, never serialized.

Two layouts:

* ``dense``  — ``(L, B, S_max, Kv, Hd)`` contiguous per sequence. GSPMD
  baseline: the sequence axis is sharded over the mesh.
* ``paged``  — global pool ``(L, P, page, Kv, Hd)`` plus **per-shard block
  tables**: the host :class:`KVPageManager` places pages round-robin across
  model shards and hands each shard its own table, so shard-local attention
  touches only resident pages (the optimized flash-decode path).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["KVCacheConfig", "DenseKVCache", "PagedKVState", "KVPageManager",
           "init_dense_cache", "init_paged_state", "dense_append",
           "paged_append", "gather_paged_kv"]


@dataclasses.dataclass(frozen=True)
class KVCacheConfig:
    n_layers: int
    n_kv_heads: int
    head_dim: int
    max_seq_len: int
    page_size: int = 128  # tokens per KV page
    num_pages: int = 0  # paged layout pool size (global)
    num_shards: int = 1  # model-axis shards owning page sub-pools
    dtype: str = "bfloat16"

    @property
    def pages_per_seq(self) -> int:
        return (self.max_seq_len + self.page_size - 1) // self.page_size

    @property
    def pages_per_shard(self) -> int:
        assert self.num_pages % max(1, self.num_shards) == 0
        return self.num_pages // max(1, self.num_shards)


class DenseKVCache(NamedTuple):
    k: jax.Array  # (L, B, S, Kv, Hd)
    v: jax.Array
    length: jax.Array  # (B,) int32 — tokens currently cached


class PagedKVState(NamedTuple):
    k_pages: jax.Array  # (L, P, page, Kv, Hd)
    v_pages: jax.Array
    # Per-shard tables: (shards, B, pages_per_seq_per_shard) LOCAL page ids,
    # -1 = hole. Entry j of shard s holds the sequence's (j*shards+s)-th page.
    block_tables: jax.Array
    length: jax.Array  # (B,) int32


def init_dense_cache(cfg: KVCacheConfig, batch: int) -> DenseKVCache:
    shape = (cfg.n_layers, batch, cfg.max_seq_len, cfg.n_kv_heads, cfg.head_dim)
    dt = jnp.dtype(cfg.dtype)
    return DenseKVCache(jnp.zeros(shape, dt), jnp.zeros(shape, dt),
                        jnp.zeros((batch,), jnp.int32))


def init_paged_state(cfg: KVCacheConfig, batch: int) -> PagedKVState:
    assert cfg.num_pages > 0, "paged layout needs num_pages"
    shape = (cfg.n_layers, cfg.num_pages, cfg.page_size, cfg.n_kv_heads,
             cfg.head_dim)
    dt = jnp.dtype(cfg.dtype)
    per_shard_slots = -(-cfg.pages_per_seq // max(1, cfg.num_shards))
    tables = jnp.full((cfg.num_shards, batch, per_shard_slots), -1, jnp.int32)
    return PagedKVState(jnp.zeros(shape, dt), jnp.zeros(shape, dt), tables,
                        jnp.zeros((batch,), jnp.int32))


# ---------------------------------------------------------------- appends
def dense_append(cache: DenseKVCache, k_new: jax.Array, v_new: jax.Array
                 ) -> DenseKVCache:
    """Write one token per sequence at position `length` (all layers at once).

    k_new/v_new: (L, B, Kv, Hd).
    """
    L, B = k_new.shape[0], k_new.shape[1]
    pos = cache.length  # (B,)
    b_idx = jnp.arange(B)
    k = cache.k.at[:, b_idx, pos].set(k_new)
    v = cache.v.at[:, b_idx, pos].set(v_new)
    return DenseKVCache(k, v, cache.length + 1)


def paged_append(state: PagedKVState, k_new: jax.Array, v_new: jax.Array,
                 physical_page: jax.Array) -> PagedKVState:
    """Write one token per sequence into its current page.

    ``physical_page``: (B,) int32 global page id of each sequence's tail page
    (resolved by the host page manager — a Handle dereference).
    k_new/v_new: (L, B, Kv, Hd).
    """
    B = k_new.shape[1]
    slot = state.length % state.k_pages.shape[2]
    b = jnp.arange(B)
    k_pages = state.k_pages.at[:, physical_page, slot].set(k_new)
    v_pages = state.v_pages.at[:, physical_page, slot].set(v_new)
    return PagedKVState(k_pages, v_pages, state.block_tables, state.length + 1)


def gather_paged_kv(state: PagedKVState, cfg: KVCacheConfig, seq: int
                    ) -> Tuple[jax.Array, jax.Array]:
    """Reference: reassemble sequence `seq`'s K/V from its pages (oracle for
    the paged-attention kernel). Returns (L, S, Kv, Hd) pair."""
    shards, _, slots = state.block_tables.shape
    ps = cfg.page_size
    chunks_k, chunks_v = [], []
    for j in range(slots):
        for s in range(shards):
            local = state.block_tables[s, seq, j]
            chunks_k.append(jnp.where(
                local >= 0,
                state.k_pages[:, s * cfg.pages_per_shard + jnp.maximum(local, 0)],
                jnp.zeros_like(state.k_pages[:, 0])))
            chunks_v.append(jnp.where(
                local >= 0,
                state.v_pages[:, s * cfg.pages_per_shard + jnp.maximum(local, 0)],
                jnp.zeros_like(state.v_pages[:, 0])))
    k = jnp.concatenate(chunks_k, axis=1)[:, : int(state.length[seq])]
    v = jnp.concatenate(chunks_v, axis=1)[:, : int(state.length[seq])]
    return k, v


# ------------------------------------------------------------- host side
class KVPageManager:
    """Host allocator for the device page pool (the buffer-pool manager).

    Pages are placed round-robin across shards so each sequence's pages are
    spread evenly — every shard sees ~1/num_shards of every sequence, which
    is what makes shard-local flash-decode load-balanced. Freed pages go on
    per-shard free lists (the recycling policy)."""

    def __init__(self, cfg: KVCacheConfig):
        self.cfg = cfg
        n = max(1, cfg.num_shards)
        self.free: List[List[int]] = [
            list(range(cfg.pages_per_shard))[::-1] for _ in range(n)]
        self.owned: Dict[int, List[Tuple[int, int]]] = {}  # seq -> [(shard, local)]
        self.written: Dict[int, int] = {}  # seq -> tokens written so far
        self.next_shard: Dict[int, int] = {}

    def pages_in_use(self) -> int:
        return sum(len(v) for v in self.owned.values())

    def allocate(self, seq: int, n_tokens: int) -> List[Tuple[int, int, int]]:
        """Reserve capacity for `n_tokens` MORE tokens beyond those written;
        returns new (shard, local_id, slot_index) placements."""
        cur = self.owned.setdefault(seq, [])
        written = self.written.setdefault(seq, 0)
        need_pages = -(-(written + n_tokens) // self.cfg.page_size) - len(cur)
        placed = []
        shard = self.next_shard.get(seq, 0)
        for _ in range(max(0, need_pages)):
            if not self.free[shard % len(self.free)]:
                # steal from the least-loaded shard (straggler mitigation)
                candidates = sorted(range(len(self.free)),
                                    key=lambda s: -len(self.free[s]))
                if not self.free[candidates[0]]:
                    raise MemoryError("KV page pool exhausted")
                shard = candidates[0]
            s = shard % len(self.free)
            local = self.free[s].pop()
            slot_index = sum(1 for (ps, _) in cur if ps == s)
            cur.append((s, local))
            placed.append((s, local, slot_index))
            shard += 1
        self.next_shard[seq] = shard
        return placed

    def advance(self, seq: int, n: int = 1) -> None:
        """Record that `n` tokens were appended to `seq`'s pages."""
        self.written[seq] = self.written.get(seq, 0) + n

    def tail_physical_page(self, seq: int) -> int:
        """Global page id receiving `seq`'s NEXT token (Handle resolution)."""
        idx = self.written.get(seq, 0) // self.cfg.page_size
        idx = min(idx, len(self.owned[seq]) - 1)
        s, local = self.owned[seq][idx]
        return s * self.cfg.pages_per_shard + local

    def release(self, seq: int) -> int:
        """Sequence finished: recycle all its pages; returns count."""
        pages = self.owned.pop(seq, [])
        for s, local in pages:
            self.free[s].append(local)
        self.next_shard.pop(seq, None)
        self.written.pop(seq, None)
        return len(pages)

    def build_tables(self, batch_seqs: List[int]) -> np.ndarray:
        """(shards, B, slots) local-id tables for the device."""
        cfg = self.cfg
        shards = max(1, cfg.num_shards)
        slots = -(-cfg.pages_per_seq // shards)
        t = np.full((shards, len(batch_seqs), slots), -1, np.int32)
        for b, seq in enumerate(batch_seqs):
            counters = [0] * shards
            for (s, local) in self.owned.get(seq, []):
                t[s, b, counters[s]] = local
                counters[s] += 1
        return t
