"""Pass 4 — static memory-footprint estimation (the admission signal).

The service's :class:`~repro.service.scheduler.AdmissionScheduler` needs
a *per-worker bytes* number before a query runs. This pass combines the
two static sources the analyzer already has:

* **cardinality** — the planner's row-estimate conventions
  (:func:`repro.core.physical.estimate_bytes` heritage: SCAN is the
  stored row count, FILTER keeps ~half, AGG collapses to ~10%, TOPK caps
  at k, FLATTEN fans out ~4×, JOIN carries the larger side);
* **width** — planlint's inferred per-edge dtypes
  (:func:`~repro.analysis.schema_pass.infer_dtypes`); columns the
  inference cannot type fall back to 8 bytes.

The total working set divides across the pool (hash-partitioned lists),
plus every broadcast-join build side replicated per worker. Static
estimates are deliberately crude — the scheduler corrects them with the
observed-bytes feedback model (:class:`~repro.service.scheduler
.FootprintModel`), so what matters here is determinism and monotonicity,
not precision.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.analysis.schema_pass import infer_dtypes
from repro.core.tcap import TCAPProgram

__all__ = ["PlanFootprint", "estimate_plan_footprint", "footprint_line",
           "modeled_join_bytes", "modeled_join_algo"]

FALLBACK_COL_BYTES = 8

# row-count multipliers per op kind (matched to the physical planner's
# estimate_bytes conventions so the two estimators never disagree on
# direction)
_FILTER_SELECTIVITY = 0.5
_AGG_REDUCTION = 0.1
_FLATTEN_FANOUT = 4.0


@dataclasses.dataclass(frozen=True)
class PlanFootprint:
    """The estimate the scheduler admits against."""

    per_list_bytes: Dict[str, float]  # list name -> estimated bytes
    total_bytes: float                # sum of all materialized lists
    per_worker_bytes: float           # total/P + replicated build sides
    scan_bytes: float                 # stored input bytes (observed base)


def _list_widths(prog: TCAPProgram, store) -> Dict[str, float]:
    """Estimated bytes per row for every list, from the inferred edge
    dtypes (fallback: 8 bytes per untyped column)."""
    widths: Dict[str, float] = {}
    counted: Dict[str, set] = {}
    for (lst, col), dt in infer_dtypes(prog, store=store).items():
        seen = counted.setdefault(lst, set())
        if col in seen:
            continue
        seen.add(col)
        widths[lst] = widths.get(lst, 0.0) + (
            dt.itemsize if isinstance(dt, np.dtype) else FALLBACK_COL_BYTES)
    return widths


def _row_walk(prog: TCAPProgram, store) -> tuple:
    """The shared cardinality walk: per-list row estimates under the
    planner's multiplier conventions, plus total scanned input bytes."""
    rows: Dict[str, float] = {}
    scan_bytes = 0.0
    for op in prog.ops:
        if op.op == "SCAN":
            try:
                s = store.get_set(op.info["set"])
                n = float(s.num_records)
                scan_bytes += n * s.dtype.itemsize
            except KeyError:
                n = 0.0
            rows[op.out] = n
        elif op.op == "FILTER":
            rows[op.out] = rows.get(op.in_list, 0.0) * _FILTER_SELECTIVITY
        elif op.op == "FLATTEN":
            rows[op.out] = rows.get(op.in_list, 0.0) * _FLATTEN_FANOUT
        elif op.op == "AGG":
            rows[op.out] = rows.get(op.in_list, 0.0) * _AGG_REDUCTION
        elif op.op == "TOPK":
            k = float(op.info.get("k", 1))
            rows[op.out] = min(rows.get(op.in_list, 0.0), k)
        elif op.op == "JOIN":
            rows[op.out] = max(rows.get(op.in_list, 0.0),
                               rows.get(op.in_list2, 0.0))
        elif op.op == "OUTPUT":
            continue
        else:  # APPLY / HASH keep cardinality
            rows[op.out] = rows.get(op.in_list, 0.0)
    return rows, scan_bytes


def estimate_plan_footprint(prog: TCAPProgram, store, plan=None,
                            num_partitions: int = 1) -> PlanFootprint:
    """Static per-worker memory estimate for one plan over ``store``.
    ``plan`` (a :class:`~repro.core.physical.PhysicalPlan`) contributes
    the broadcast-join decisions — each broadcast build side is resident
    in full on every worker (P× replicated cluster-wide): per worker it
    costs the 1/P base share plus (P-1)/P replicated bytes, and the
    total counts all P copies. With P=1 nothing replicates."""
    P = max(1, num_partitions)
    widths = _list_widths(prog, store)
    rows, scan_bytes = _row_walk(prog, store)
    per_list: Dict[str, float] = {}
    broadcast_extra = 0.0   # per-worker bytes beyond the 1/P base share
    replicated = 0.0        # cluster-wide extra copies (P-1 of each build)

    def width(lst: str) -> float:
        return widths.get(lst) or float(FALLBACK_COL_BYTES)

    for op in prog.ops:
        if op.op == "OUTPUT":
            continue
        per_list[op.out] = rows.get(op.out, 0.0) * width(op.out)
        if (op.op == "JOIN" and plan is not None
                and plan.join_algo.get(id(op)) == "broadcast"):
            build = rows.get(op.in_list2, 0.0) * width(op.in_list2)
            broadcast_extra += build * (P - 1) / P
            replicated += build * (P - 1)

    base_total = sum(per_list.values())
    total = base_total + replicated
    per_worker = base_total / P + broadcast_extra
    return PlanFootprint(per_list_bytes=per_list, total_bytes=total,
                         per_worker_bytes=per_worker,
                         scan_bytes=scan_bytes)


def modeled_join_bytes(prog: TCAPProgram, store
                       ) -> Dict[int, tuple]:
    """Width-aware join input sizes: JOIN op index -> (probe_bytes,
    build_bytes), rows from the shared cardinality walk × inferred
    per-column itemsize. Unlike the planner's
    :func:`~repro.core.physical.estimate_bytes` — which traces catalog
    record itemsize through the pipeline — this sees projections and
    aggregations *narrow* the stream, which is exactly where the two
    models disagree (PL203)."""
    widths = _list_widths(prog, store)
    rows, _ = _row_walk(prog, store)

    def width(lst: str) -> float:
        return widths.get(lst) or float(FALLBACK_COL_BYTES)

    return {i: (rows.get(op.in_list, 0.0) * width(op.in_list),
                rows.get(op.in_list2, 0.0) * width(op.in_list2))
            for i, op in enumerate(prog.ops) if op.op == "JOIN"}


def modeled_join_algo(prog: TCAPProgram, store,
                      broadcast_threshold: int = 2 << 30,
                      num_partitions=None) -> Dict[int, str]:
    """The broadcast-vs-hash choice the width-aware model makes: JOIN op
    index -> algorithm, under the *same* threshold and transfer-cost
    rules as :func:`~repro.core.physical.plan_physical` (broadcast ships
    build×(P-1); a shuffle ships (build+probe)×(P-1)/P) so the only
    possible source of disagreement is the byte model. PL203 reports a
    disagreement; ``plan_physical(advise_joins=True)`` adopts this
    choice."""
    out: Dict[int, str] = {}
    for i, (probe, build) in modeled_join_bytes(prog, store).items():
        choice = ("broadcast" if build < broadcast_threshold
                  else "hash_partition")
        if choice == "broadcast" and num_partitions and num_partitions > 1:
            P = num_partitions
            if build * (P - 1) > (build + probe) * (P - 1) / P:
                choice = "hash_partition"
        out[i] = choice
    return out


def footprint_line(fp: PlanFootprint, num_partitions: int) -> str:
    """One human line for explain()/planlint surfaces."""
    return (f"footprint: ~{fp.total_bytes:,.0f} bytes total, "
            f"~{fp.per_worker_bytes:,.0f}/worker across "
            f"{num_partitions} partitions")
