"""Pass 2 — partitioning-property propagation and redundant-exchange
detection.

The AGG exchange routes each pre-aggregated group by
``stable_key_hash(key tuple) % P`` (:meth:`~repro.core.relops.AggMap
.split_by_key_hash`). Its *output* is therefore a stream hash-partitioned
on the ordered key tuple by that hash family — a fact this pass threads
forward through the pipelined ops:

* APPLY/FILTER/HASH/FLATTEN keep rows in place — the fact survives;
* a broadcast JOIN keeps probe-side rows in place — the probe fact
  survives (the build side is replicated, its facts do not);
* a hash-partition JOIN re-routes both sides by ``hash_col % P`` — a
  *different* hash family, so incoming ``stable_key_hash`` facts die (the
  two families must never satisfy each other's placement);
* TOPK gathers to one rank — facts die.

Column *values* are tracked by structural value ids so the fact follows
the value, not the column name: an AGG key packed into a record column
(the ``pack`` stage the compiler inserts between chained aggregations)
and re-extracted by ``attAccess`` resolves back to the original key's id.

Where a downstream AGG's ordered key-id tuple equals a live fact, its
exchange is redundant: every partition's partial map already holds only
keys routing to itself, so split+merge is the identity permutation — the
optimizer elides the exchange with byte-identical results (**PL201**).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.analysis.diagnostics import Diagnostic, op_path
from repro.core.relops import AggSpec
from repro.core.tcap import TCAPProgram

__all__ = ["propagate_partitioning", "PartitioningResult"]


class PartitioningResult:
    """``redundant``: AGG op indices whose exchange a live fact satisfies;
    ``diagnostics``: one PL201 per such op; ``facts``: the surviving fact
    (ordered key value-id tuple) per list name, for explain/debugging."""

    def __init__(self, redundant: Tuple[int, ...],
                 diagnostics: List[Diagnostic],
                 facts: Dict[str, Optional[Tuple]]):
        self.redundant = redundant
        self.diagnostics = diagnostics
        self.facts = facts


def propagate_partitioning(prog: TCAPProgram,
                           join_algo_by_index: Optional[Dict[int, str]]
                           = None) -> PartitioningResult:
    """``join_algo_by_index`` maps JOIN op index -> "broadcast" |
    "hash_partition" (from the physical plan). Without it every JOIN is
    assumed hash-partitioned — the conservative choice: facts die."""
    vid: Dict[Tuple[str, str], Tuple] = {}  # (list, col) -> value id
    fact: Dict[str, Optional[Tuple]] = {}   # list -> ordered key-vid tuple
    redundant: List[int] = []
    diags: List[Diagnostic] = []

    def gv(lst: str, col: str) -> Tuple:
        # defensive: an edge the walk never defined still gets a stable,
        # per-column id (same column -> same value, so this stays sound)
        return vid.get((lst, col), ("missing", lst, col))

    def copy_vids(op) -> None:
        for c in op.copy_cols:
            vid[(op.out, c)] = gv(op.in_list, c)
        for c in op.copy_cols2:
            vid[(op.out, c)] = gv(op.in_list2, c)

    for i, op in enumerate(prog.ops):
        if op.op == "SCAN":
            vid[(op.out, op.out_cols[0])] = ("scan", i)
            fact[op.out] = None
            continue
        if op.op == "APPLY":
            copy_vids(op)
            if (newc := op.new_cols):
                t = op.info.get("type")
                ins = tuple(gv(op.in_list, c) for c in op.apply_cols)
                if t == "rename":
                    v = ins[0]
                elif t == "attAccess":
                    base = ins[0]
                    att = op.info["attName"]
                    if base[0] == "pack" and att in base[1]:
                        # re-extracting a packed field resolves to the
                        # original value — the chained-AGG key path
                        v = base[2][base[1].index(att)]
                    else:
                        v = ("att", base, att)
                elif t == "pack":
                    names = tuple(op.info["fields"].split(","))
                    v = ("pack", names, ins)
                elif t == "const":
                    # repr, not the raw value: array-valued constants must
                    # not leak elementwise == into fact comparison
                    val = op.info["value"]
                    v = ("const", type(val).__name__, repr(val))
                elif t == "methodCall":
                    v = ("method", op.info["onType"],
                         op.info["methodName"], ins)
                elif t in ("cmp", "bool", "arith"):
                    v = (t, op.info.get("op"), ins)
                else:  # native and anything future: a fresh opaque value
                    v = ("opaque", i)
                vid[(op.out, newc[0])] = v
            fact[op.out] = fact.get(op.in_list)
        elif op.op in ("FILTER", "HASH"):
            copy_vids(op)
            if op.op == "HASH":
                vid[(op.out, op.new_cols[0])] = (
                    "hash", gv(op.in_list, op.apply_cols[0]))
            # filtering/annotating keeps every row in its partition
            fact[op.out] = fact.get(op.in_list)
        elif op.op == "FLATTEN":
            copy_vids(op)
            vid[(op.out, op.out_cols[0])] = (
                "flat", gv(op.in_list, op.apply_cols[0]))
            # expanded rows inherit their source row's partition, and the
            # copied key values repeat in place — the fact survives
            fact[op.out] = fact.get(op.in_list)
        elif op.op == "JOIN":
            copy_vids(op)
            algo = ((join_algo_by_index or {}).get(i, "hash_partition"))
            if algo == "broadcast":
                # probe rows never move; build side is replicated
                fact[op.out] = fact.get(op.in_list)
            else:
                # both sides re-routed by hash_col % P — a different hash
                # family than stable_key_hash, so no fact survives
                fact[op.out] = None
        elif op.op == "AGG":
            spec = AggSpec.from_op(op)
            kvids = tuple(gv(op.in_list, c) for c in spec.key_cols(op))
            live = fact.get(op.in_list)
            if (live is not None and live == kvids
                    and not any(v[0] == "opaque" for v in kvids)):
                redundant.append(i)
                diags.append(Diagnostic(
                    "PL201", "info",
                    "redundant exchange: input is already hash-partitioned "
                    f"on {list(spec.key_names)} by stable_key_hash — the "
                    "AGG shuffle is the identity permutation and is elided",
                    op_path(i, op)))
            for kname, kv in zip(spec.key_names, kvids):
                vid[(op.out, kname)] = kv
            for name in spec.out_names:
                vid[(op.out, name)] = ("agg", i, name)
            # the exchange leaves (or elision keeps) every group on the
            # rank its key hashes to: the output carries the fact
            fact[op.out] = kvids
        elif op.op == "TOPK":
            for c in op.out_cols:
                vid[(op.out, c)] = ("topk", i, c)
            fact[op.out] = None  # global gather to one rank
        elif op.op == "OUTPUT":
            fact[op.out] = fact.get(op.in_list)

    return PartitioningResult(tuple(redundant), diags, fact)
