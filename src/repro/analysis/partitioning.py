"""Pass 2 — partitioning-property propagation and redundant-exchange
detection (PL201 / PL202).

Both exchange families route by the *same* hash: the AGG exchange sends
each pre-aggregated group to ``stable_key_hash(key) % P``
(:meth:`~repro.core.relops.AggMap.split_by_key_hash`) and the
hash-partition JOIN shuffle sends each row to ``hash_col(key col) % P``
(:func:`~repro.core.relops.split_by_hash`), and ``hash_col`` is
bit-identical per element to ``stable_key_hash``. A stream placed by one
family therefore satisfies the other's placement — which is what lets
partitioning *facts* flow through joins instead of unconditionally dying.

A fact is a set of ordered key value-id tuples the stream is
hash-partitioned on; the pass threads facts forward:

* SCAN starts with no facts (pages are placed by load balance, not key);
* APPLY/FILTER/HASH/FLATTEN keep rows in place — facts survive;
* a broadcast JOIN keeps probe-side rows in place — probe facts survive
  (the build side is replicated, its facts do not);
* a hash-partition JOIN routes each side by its join-key hash. If a side
  already carries a single-key fact on exactly that value, its
  split+route exchange is the identity permutation — **PL202**, the
  side's exchange is elided (``join_elide``) and the side's whole fact
  set survives. Whether or not a side elides, the *output* is
  hash-partitioned on both join keys (rows land where their key hashes),
  so the join adds ``{(probe key,), (build key,)}`` to the outgoing
  facts — this is what a downstream AGG on the join key consumes;
* AGG: where the ordered key-id tuple is a member of the live fact set,
  its exchange is redundant (**PL201**, elided); either way the output
  carries the key-tuple fact;
* TOPK gathers to one rank — facts die.

Column *values* are tracked by structural value ids so a fact follows the
value, not the column name: an AGG key packed into a record column (the
``pack`` stage between chained aggregations, or the default join
projection's pair pack — threaded via the op's ``pair_fields``
provenance) and re-extracted by ``attAccess`` resolves back to the
original key's id.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, List, Optional, Tuple

from repro.analysis.diagnostics import Diagnostic, op_path
from repro.core.relops import AggSpec
from repro.core.tcap import TCAPProgram

__all__ = ["propagate_partitioning", "PartitioningResult"]

_NO_FACTS: FrozenSet[Tuple] = frozenset()

_SIDE_LABEL = {"L": "probe", "R": "build"}


class PartitioningResult:
    """``redundant``: AGG op indices whose exchange a live fact satisfies;
    ``join_elide``: JOIN op index -> sides ("L" probe / "R" build) whose
    shuffle a live fact satisfies; ``diagnostics``: one PL201/PL202 per
    elision; ``facts``: the surviving fact set (ordered key value-id
    tuples) per list name, for explain/debugging."""

    def __init__(self, redundant: Tuple[int, ...],
                 diagnostics: List[Diagnostic],
                 facts: Dict[str, FrozenSet[Tuple]],
                 join_elide: Optional[Dict[int, Tuple[str, ...]]] = None):
        self.redundant = redundant
        self.diagnostics = diagnostics
        self.facts = facts
        self.join_elide = dict(join_elide or {})


def _usable(v: Tuple) -> bool:
    # opaque values (native lambdas, futures) and edges the walk never
    # defined can't be proven equal to anything — never carry facts
    return v[0] not in ("opaque", "missing")


def propagate_partitioning(prog: TCAPProgram,
                           join_algo_by_index: Optional[Dict[int, str]]
                           = None) -> PartitioningResult:
    """``join_algo_by_index`` maps JOIN op index -> "broadcast" |
    "hash_partition" (from the physical plan). Without it every JOIN is
    assumed hash-partitioned — still productive: the hash shuffle itself
    creates join-key facts, and co-partitioned sides elide."""
    vid: Dict[Tuple[str, str], Tuple] = {}    # (list, col) -> value id
    fact: Dict[str, FrozenSet[Tuple]] = {}    # list -> key-vid tuples
    redundant: List[int] = []
    join_elide: Dict[int, Tuple[str, ...]] = {}
    diags: List[Diagnostic] = []

    def gv(lst: str, col: str) -> Tuple:
        # defensive: an edge the walk never defined still gets a stable,
        # per-column id (same column -> same value, so this stays sound)
        return vid.get((lst, col), ("missing", lst, col))

    def copy_vids(op) -> None:
        for c in op.copy_cols:
            vid[(op.out, c)] = gv(op.in_list, c)
        for c in op.copy_cols2:
            vid[(op.out, c)] = gv(op.in_list2, c)

    def att_of(base: Tuple, att: str) -> Tuple:
        # accessing a packed field resolves to the original value — the
        # chained-AGG / join-pair key path
        if base[0] == "pack" and att in base[1]:
            return base[2][base[1].index(att)]
        return ("att", base, att)

    def hash_key(lst: str, hash_col: str) -> Optional[Tuple]:
        # the value a HASH column was computed over, if trackable
        hv = gv(lst, hash_col)
        if hv[0] == "hash" and _usable(hv[1]):
            return hv[1]
        return None

    for i, op in enumerate(prog.ops):
        if op.op == "SCAN":
            vid[(op.out, op.out_cols[0])] = ("scan", i)
            fact[op.out] = _NO_FACTS
            continue
        if op.op == "APPLY":
            copy_vids(op)
            if (newc := op.new_cols):
                t = op.info.get("type")
                ins = tuple(gv(op.in_list, c) for c in op.apply_cols)
                if t == "rename":
                    v = ins[0]
                elif t == "attAccess":
                    v = att_of(ins[0], op.info["attName"])
                elif t == "pack":
                    names = tuple(op.info["fields"].split(","))
                    v = ("pack", names, ins)
                elif t == "const":
                    # repr, not the raw value: array-valued constants must
                    # not leak elementwise == into fact comparison
                    val = op.info["value"]
                    v = ("const", type(val).__name__, repr(val))
                elif t == "methodCall":
                    v = ("method", op.info["onType"],
                         op.info["methodName"], ins)
                elif t in ("cmp", "bool", "arith"):
                    v = (t, op.info.get("op"), ins)
                elif t == "native" and "pair_fields" in op.info:
                    # the default join projection: a native pack whose
                    # per-field provenance the front-end recorded — each
                    # output field is an attAccess on one input record
                    moves = tuple(tuple(m) for m in op.info["pair_fields"])
                    sides = (ins[0] if len(ins) > 0 else ("missing", "", ""),
                             ins[1] if len(ins) > 1 else ("missing", "", ""))
                    v = ("pack", tuple(m[0] for m in moves),
                         tuple(att_of(sides[m[1]], m[2]) for m in moves))
                else:  # native and anything future: a fresh opaque value
                    v = ("opaque", i)
                vid[(op.out, newc[0])] = v
            fact[op.out] = fact.get(op.in_list, _NO_FACTS)
        elif op.op in ("FILTER", "HASH"):
            copy_vids(op)
            if op.op == "HASH":
                vid[(op.out, op.new_cols[0])] = (
                    "hash", gv(op.in_list, op.apply_cols[0]))
            # filtering/annotating keeps every row in its partition
            fact[op.out] = fact.get(op.in_list, _NO_FACTS)
        elif op.op == "FLATTEN":
            copy_vids(op)
            vid[(op.out, op.out_cols[0])] = (
                "flat", gv(op.in_list, op.apply_cols[0]))
            # expanded rows inherit their source row's partition, and the
            # copied key values repeat in place — the fact survives
            fact[op.out] = fact.get(op.in_list, _NO_FACTS)
        elif op.op == "JOIN":
            copy_vids(op)
            algo = ((join_algo_by_index or {}).get(i, "hash_partition"))
            if algo == "broadcast":
                # probe rows never move; build side is replicated
                fact[op.out] = fact.get(op.in_list, _NO_FACTS)
            else:
                # both sides routed by hash_col(join key) % P — the same
                # hash family as stable_key_hash (bit-identical), so a
                # side already partitioned on exactly its join key needs
                # no exchange, and the output is partitioned on both keys
                lkv = hash_key(op.in_list, op.apply_cols[0])
                rkv = hash_key(op.in_list2, op.apply_cols2[0])
                out = set()
                if lkv is not None:
                    out.add((lkv,))
                if rkv is not None:
                    out.add((rkv,))
                sides: List[str] = []
                for side, kv, in_lst in (("L", lkv, op.in_list),
                                         ("R", rkv, op.in_list2)):
                    live = fact.get(in_lst, _NO_FACTS)
                    if kv is not None and (kv,) in live:
                        sides.append(side)
                        out |= live
                if sides:
                    join_elide[i] = tuple(sides)
                    diags.append(Diagnostic(
                        "PL202", "info",
                        "co-partitioned join: "
                        + " and ".join(_SIDE_LABEL[s] for s in sides)
                        + " side is already hash-partitioned on its join "
                        "key — the split+route exchange is the identity "
                        "permutation and is elided",
                        op_path(i, op)))
                fact[op.out] = frozenset(out)
        elif op.op == "AGG":
            spec = AggSpec.from_op(op)
            kvids = tuple(gv(op.in_list, c) for c in spec.key_cols(op))
            live = fact.get(op.in_list, _NO_FACTS)
            if kvids in live and all(_usable(v) for v in kvids):
                redundant.append(i)
                diags.append(Diagnostic(
                    "PL201", "info",
                    "redundant exchange: input is already hash-partitioned "
                    f"on {list(spec.key_names)} by stable_key_hash — the "
                    "AGG shuffle is the identity permutation and is elided",
                    op_path(i, op)))
            for kname, kv in zip(spec.key_names, kvids):
                vid[(op.out, kname)] = kv
            for name in spec.out_names:
                vid[(op.out, name)] = ("agg", i, name)
            # the exchange leaves (or elision keeps) every group on the
            # rank its key hashes to: the output carries the fact
            fact[op.out] = (frozenset({kvids})
                            if all(_usable(v) for v in kvids)
                            else _NO_FACTS)
        elif op.op == "TOPK":
            for c in op.out_cols:
                vid[(op.out, c)] = ("topk", i, c)
            fact[op.out] = _NO_FACTS  # global gather to one rank
        elif op.op == "OUTPUT":
            fact[op.out] = fact.get(op.in_list, _NO_FACTS)

    return PartitioningResult(tuple(redundant), diags, fact, join_elide)
