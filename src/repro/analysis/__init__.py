"""planlint — compile-time static analysis over TCAP plans.

Three passes (schema/dtype dataflow, partitioning-property propagation,
capability & fusion checking) producing structured
:class:`~repro.analysis.diagnostics.Diagnostic` findings with stable
codes. Surfaces: ``Dataset.check()``, ``Dataset.explain(
diagnostics=True)``, and ``python -m repro.analysis`` over the bundled
apps. Every plan the Session executes must analyze clean at error
severity.
"""
from repro.analysis.analyzer import analyze
from repro.analysis.capability import (BuildConfig, capability_diagnostics,
                                       check_session_config,
                                       check_worker_config)
from repro.analysis.diagnostics import AnalysisReport, Diagnostic
from repro.analysis.footprint import (PlanFootprint,
                                      estimate_plan_footprint)
from repro.analysis.partitioning import propagate_partitioning
from repro.analysis.schema_pass import schema_pass

__all__ = ["AnalysisReport", "BuildConfig", "Diagnostic", "PlanFootprint",
           "analyze", "capability_diagnostics", "check_session_config",
           "check_worker_config", "estimate_plan_footprint",
           "propagate_partitioning", "schema_pass"]
