"""The analyzer orchestrator: run every pass over one optimized program
and fold the findings into a single :class:`~repro.analysis.diagnostics
.AnalysisReport`.

``analyze()`` is what ``Dataset.check()``, ``explain(diagnostics=True)``,
the ``python -m repro.analysis`` CLI, and the Session's execution gate all
call — one entry point, so a plan the gate accepts is exactly a plan the
inspection surfaces report clean at error severity.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.analysis.capability import BuildConfig, capability_diagnostics
from repro.analysis.diagnostics import AnalysisReport, Diagnostic, op_path
from repro.analysis.partitioning import propagate_partitioning
from repro.analysis.schema_pass import schema_pass
from repro.core.exprc import FusedStage, build_steps, schedule_jax_run
from repro.core.tcap import TCAPProgram

__all__ = ["analyze"]


def _join_algo_by_index(prog: TCAPProgram, plan) -> Optional[Dict[int, str]]:
    if plan is None:
        return None
    return {i: plan.join_algo.get(id(op), "hash_partition")
            for i, op in enumerate(prog.ops) if op.op == "JOIN"}


def _fusion_diagnostics(prog: TCAPProgram, edge_dtypes,
                        expr_backend: str) -> List[Diagnostic]:
    """Pass 3b — what breaks fusion runs (PL401) and, on the jax backend,
    which fused runs bounce back to the host after their jitted core
    (PL402). The interp backend never fuses, so it gets neither."""
    diags: List[Diagnostic] = []
    if expr_backend == "interp":
        return diags
    for i, op in enumerate(prog.ops):
        if op.op == "APPLY" and op.info.get("type") == "native":
            diags.append(Diagnostic(
                "PL401", "info",
                f"fusion barrier: native lambda {op.info.get('name', op.stage)!r} "
                "is opaque to the stage compiler — the pipelined run splits "
                "here and intermediate vector lists materialize", op_path(i, op)))
        elif op.op == "FLATTEN":
            diags.append(Diagnostic(
                "PL401", "info",
                "fusion barrier: FLATTEN re-shapes the row space and cannot "
                "join a fused run", op_path(i, op)))
    if expr_backend != "jax":
        return diags
    # walk the compiled step plan with op indices preserved (the worker
    # runtime's convention) so PL402 lands on the run's first op
    steps = build_steps(prog, "jax")
    i = -1
    for step in steps:
        if not isinstance(step, FusedStage):
            i += 1
            continue
        first = i + 1
        i += len(step.ops)
        ir = step.ir
        in_dts = [edge_dtypes.get((step.in_list, c)) for c in ir.in_cols]
        if any(d is None for d in in_dts):
            continue  # inference gave up upstream; nothing sound to say
        status, _ = schedule_jax_run(
            ir, [np.zeros(0, d) for d in in_dts])
        n_core = sum(1 for ins in ir.instrs if status[ins.out] == "jit")
        n_post = sum(1 for ins in ir.instrs if status[ins.out] == "post")
        if n_core and n_post:
            kinds = sorted({ins.kind for ins in ir.instrs
                            if status[ins.out] == "post"})
            diags.append(Diagnostic(
                "PL402", "info",
                f"host-device round-trip: {n_post} instruction(s) "
                f"({', '.join(kinds)}) return to the host after the jitted "
                f"core of this fused run — non-jaxable dtypes or host-only "
                "stages downstream of device values",
                op_path(first, prog.ops[first])))
    return diags


def analyze(prog: TCAPProgram, store=None, plan=None,
            config: Optional[BuildConfig] = None,
            expr_backend: Optional[str] = None) -> AnalysisReport:
    """Run schema/dtype dataflow, partitioning propagation, and the
    capability + fusion rules over one (optimized) TCAP program.

    ``store`` resolves SCAN dtypes for untyped sets; ``plan`` (a
    :class:`~repro.core.physical.PhysicalPlan`) feeds the partitioning
    pass the join-algorithm decisions; ``config`` enables the build-config
    capability rules. All three are optional — passes degrade
    conservatively without them."""
    if expr_backend is None:
        expr_backend = config.expr_backend if config is not None else "numpy"
    diags, edge_dtypes, output_schema = schema_pass(prog, store)
    part = propagate_partitioning(prog, _join_algo_by_index(prog, plan))
    diags = list(diags) + list(part.diagnostics)
    diags += capability_diagnostics(prog, config)
    diags += _fusion_diagnostics(prog, edge_dtypes, expr_backend)
    order = {"error": 0, "warning": 1, "info": 2}
    diags.sort(key=lambda d: (order[d.severity], d.op_path, d.code))
    # PL201 states the *finding* (the exchange is provably redundant) and
    # stays either way; elided_exchanges states the *action* — what this
    # plan will actually skip (empty when the session disables elision)
    elided = part.redundant
    if plan is not None:
        elided = tuple(i for i, op in enumerate(prog.ops)
                       if id(op) in plan.agg_elide)
    return AnalysisReport(diagnostics=diags, output_schema=output_schema,
                          elided_exchanges=elided)
