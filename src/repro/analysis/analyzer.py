"""The analyzer orchestrator: run every pass over one optimized program
and fold the findings into a single :class:`~repro.analysis.diagnostics
.AnalysisReport`.

``analyze()`` is what ``Dataset.check()``, ``explain(diagnostics=True)``,
the ``python -m repro.analysis`` CLI, and the Session's execution gate all
call — one entry point, so a plan the gate accepts is exactly a plan the
inspection surfaces report clean at error severity.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.analysis.capability import BuildConfig, capability_diagnostics
from repro.analysis.diagnostics import AnalysisReport, Diagnostic, op_path
from repro.analysis.partitioning import propagate_partitioning
from repro.analysis.schema_pass import schema_pass
from repro.core.exprc import FusedStage, build_steps, schedule_jax_run
from repro.core.tcap import TCAPProgram

__all__ = ["analyze"]


def _join_algo_by_index(prog: TCAPProgram, plan) -> Optional[Dict[int, str]]:
    if plan is None:
        return None
    return {i: plan.join_algo.get(id(op), "hash_partition")
            for i, op in enumerate(prog.ops) if op.op == "JOIN"}


def _fusion_diagnostics(prog: TCAPProgram, edge_dtypes,
                        expr_backend: str) -> List[Diagnostic]:
    """Pass 3b — what breaks fusion runs (PL401) and, on the jax backend,
    which fused runs bounce back to the host after their jitted core
    (PL402). The interp backend never fuses, so it gets neither."""
    diags: List[Diagnostic] = []
    if expr_backend == "interp":
        return diags
    for i, op in enumerate(prog.ops):
        if op.op == "APPLY" and op.info.get("type") == "native":
            diags.append(Diagnostic(
                "PL401", "info",
                f"fusion barrier: native lambda {op.info.get('name', op.stage)!r} "
                "is opaque to the stage compiler — the pipelined run splits "
                "here and intermediate vector lists materialize", op_path(i, op)))
        elif op.op == "FLATTEN":
            diags.append(Diagnostic(
                "PL401", "info",
                "fusion barrier: FLATTEN re-shapes the row space and cannot "
                "join a fused run", op_path(i, op)))
    if expr_backend != "jax":
        return diags
    # walk the compiled step plan with op indices preserved (the worker
    # runtime's convention) so PL402 lands on the run's first op
    steps = build_steps(prog, "jax")
    i = -1
    for step in steps:
        if not isinstance(step, FusedStage):
            i += 1
            continue
        first = i + 1
        i += len(step.ops)
        ir = step.ir
        in_dts = [edge_dtypes.get((step.in_list, c)) for c in ir.in_cols]
        if any(d is None for d in in_dts):
            continue  # inference gave up upstream; nothing sound to say
        arrays = [np.zeros(0, d) for d in in_dts]
        # the raw schedule names the finding; the hoisted schedule (what
        # _compile_jax actually builds) shows the action taken on it
        raw, _ = schedule_jax_run(ir, arrays, hoist_host=False)
        n_core = sum(1 for ins in ir.instrs if raw[ins.out] == "jit")
        n_post = sum(1 for ins in ir.instrs if raw[ins.out] == "post")
        if n_core and n_post:
            kinds = sorted({ins.kind for ins in ir.instrs
                            if raw[ins.out] == "post"})
            hoisted, _ = schedule_jax_run(ir, arrays, hoist_host=True)
            n_demoted = sum(1 for ins in ir.instrs
                            if raw[ins.out] == "jit"
                            and hoisted[ins.out] != "jit")
            diags.append(Diagnostic(
                "PL402", "info",
                f"host-device round-trip: {n_post} instruction(s) "
                f"({', '.join(kinds)}) would return to the host after the "
                "jitted core of this fused run (non-jaxable dtypes or "
                "host-only stages downstream of device values) — the "
                "scheduler reorders them ahead of the core, demoting "
                f"{n_demoted} numeric instruction(s) to the host prologue "
                "for a single device crossing",
                op_path(first, prog.ops[first])))
    return diags


def _join_advisories(prog: TCAPProgram, store, plan,
                     broadcast_threshold: int,
                     num_partitions: Optional[int]) -> List[Diagnostic]:
    """Pass 5 — PL203: cross-check the plan's broadcast-vs-hash choice
    against the width-aware byte model (inferred per-column itemsize ×
    catalog cardinality). The planner's trace carries the scanned record
    itemsize through projections and aggregations, so a narrowed build
    side can look big to it; where the two models disagree, advise."""
    from repro.analysis.footprint import modeled_join_algo
    if plan is None or store is None:
        return []
    if not any(op.op == "JOIN" for op in prog.ops):
        return []  # the width model re-walks inference; skip join-free plans
    advised = modeled_join_algo(prog, store, broadcast_threshold,
                                num_partitions)
    diags: List[Diagnostic] = []
    for i, op in enumerate(prog.ops):
        if op.op != "JOIN" or i not in advised:
            continue
        chosen = plan.join_algo.get(id(op), "hash_partition")
        if advised[i] != chosen:
            diags.append(Diagnostic(
                "PL203", "info",
                f"join algorithm disagreement: the plan chose {chosen} "
                f"but modeled bytes (inferred itemsize x cardinality) "
                f"favor {advised[i]} — plan_physical(advise_joins=True) "
                "or Session(advise_joins=True) adopts the modeled choice",
                op_path(i, op)))
    return diags


def analyze(prog: TCAPProgram, store=None, plan=None,
            config: Optional[BuildConfig] = None,
            expr_backend: Optional[str] = None,
            broadcast_threshold: int = 2 << 30,
            num_partitions: Optional[int] = None) -> AnalysisReport:
    """Run schema/dtype dataflow, partitioning propagation, and the
    capability + fusion + join-advisory rules over one (optimized) TCAP
    program.

    ``store`` resolves SCAN dtypes for untyped sets; ``plan`` (a
    :class:`~repro.core.physical.PhysicalPlan`) feeds the partitioning
    pass the join-algorithm decisions; ``config`` enables the build-config
    capability rules; ``broadcast_threshold``/``num_partitions`` let the
    PL203 cross-check price joins under the session's actual planner
    inputs. All are optional — passes degrade conservatively without
    them."""
    if expr_backend is None:
        expr_backend = config.expr_backend if config is not None else "numpy"
    diags, edge_dtypes, output_schema = schema_pass(prog, store)
    part = propagate_partitioning(prog, _join_algo_by_index(prog, plan))
    diags = list(diags) + list(part.diagnostics)
    diags += capability_diagnostics(prog, config)
    diags += _fusion_diagnostics(prog, edge_dtypes, expr_backend)
    diags += _join_advisories(prog, store, plan, broadcast_threshold,
                              num_partitions)
    order = {"error": 0, "warning": 1, "info": 2}
    diags.sort(key=lambda d: (order[d.severity], d.op_path, d.code))
    # PL201/PL202 state the *finding* (the exchange is provably redundant)
    # and stay either way; elided_exchanges states the *action* — the op
    # indices whose exchange this plan will actually skip (empty when the
    # session disables elision)
    elided = tuple(sorted(set(part.redundant) | set(part.join_elide)))
    if plan is not None:
        elided = tuple(i for i, op in enumerate(prog.ops)
                       if id(op) in plan.agg_elide
                       or id(op) in plan.join_elide)
    return AnalysisReport(diagnostics=diags, output_schema=output_schema,
                          elided_exchanges=elided)
