"""Pass 3a — capability rules: build-time configuration validation.

Historically these checks lived as ad-hoc ``ValueError``s scattered
through ``Session.__init__`` and ``DistributedExecutor.__init__``. They
are now analyzer rules evaluated in one place, in a *fixed order*, with
the exact exception types and messages preserved — ``Session`` and the
driver call :func:`check_session_config` / :func:`check_worker_config`
instead of duplicating the checks.

Plan-level capability checking (:func:`capability_diagnostics`) runs per
compiled program: a native Python lambda in a plan bound for
``socket_launch='connect'`` workers cannot cross the wire (**PL301**,
error severity — the Session refuses to execute the plan, long before the
rendezvous would fail).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.analysis.diagnostics import Diagnostic, op_path
from repro.core.exprc import EXPR_BACKENDS
from repro.core.tcap import TCAPProgram

__all__ = ["BuildConfig", "SOCKET_LAUNCHES", "capability_diagnostics",
           "check_session_config", "check_worker_config",
           "session_config_violation", "worker_config_violation"]

SOCKET_LAUNCHES = ("fork", "thread", "connect")


@dataclasses.dataclass(frozen=True)
class BuildConfig:
    """The session/executor knobs the capability rules reason about."""

    backend: str = "local"
    num_partitions: Optional[int] = None
    num_workers: Optional[int] = None
    worker_kind: Optional[str] = None
    socket_launch: Optional[str] = None
    socket_addr: Optional[Tuple[str, int]] = None
    expr_backend: str = "numpy"
    plan_cache_size: int = 64
    custom_executor: bool = False  # executor_cls other than the default
    has_service: bool = False      # a QueryService was passed (service=)


# ------------------------------------------------------ session-level
def session_config_violation(cfg: BuildConfig) -> Optional[str]:
    """The first violated session rule's message, or None. Rule order is
    part of the contract: a config violating several rules must raise the
    same message it always did."""
    if cfg.expr_backend not in EXPR_BACKENDS:
        return (f"unknown expr_backend {cfg.expr_backend!r} "
                f"(expected one of {EXPR_BACKENDS})")
    if cfg.backend == "workers":
        if cfg.custom_executor:
            return ("backend='workers' chooses its own executor — drop the "
                    "executor_cls argument")
        if (cfg.num_partitions is not None and cfg.num_workers is not None
                and cfg.num_partitions != cfg.num_workers):
            return (f"num_partitions={cfg.num_partitions} and "
                    f"num_workers={cfg.num_workers} disagree — the workers "
                    "backend takes one worker per partition; pass just "
                    "num_workers")
        if (cfg.worker_kind == "socket" and cfg.socket_launch == "connect"
                and cfg.num_workers is None and cfg.num_partitions is None):
            return ("worker_kind='socket' with socket_launch='connect' "
                    "needs an explicit num_workers — the driver must know "
                    "how many external workers to await at the rendezvous")
    elif cfg.backend == "service":
        if not cfg.has_service:
            return ("backend='service' attaches to a running QueryService "
                    "— pass service=<QueryService> (or use "
                    "Session.connect(service))")
        if cfg.custom_executor:
            return ("backend='service' chooses its own executor — drop "
                    "the executor_cls argument")
        if cfg.num_workers is not None or cfg.num_partitions is not None:
            return ("the worker pool size is fixed by the QueryService — "
                    "drop num_workers/num_partitions for "
                    "backend='service'")
        if cfg.worker_kind is not None:
            return ("worker_kind is fixed by the QueryService's launch "
                    "mode — drop it for backend='service'")
        if cfg.socket_launch is not None or cfg.socket_addr is not None:
            return ("socket_launch/socket_addr are fixed by the "
                    "QueryService — drop them for backend='service'")
    elif cfg.backend == "local":
        if cfg.num_workers is not None:
            return ("num_workers only applies to backend='workers' "
                    "(use num_partitions for the local simulation)")
        if cfg.worker_kind is not None:
            return ("worker_kind only applies to backend='workers' "
                    "(the local backend simulates partitions in-process)")
        if cfg.socket_launch is not None or cfg.socket_addr is not None:
            return ("socket_launch/socket_addr only apply to "
                    "backend='workers' with worker_kind='socket'")
    else:
        return (f"unknown backend {cfg.backend!r} "
                "(expected 'local', 'workers', or 'service')")
    if cfg.backend != "service" and cfg.has_service:
        return ("service= only applies to backend='service' — a "
                "QueryService was passed but this session would not "
                "use it")
    if cfg.plan_cache_size < 1:
        return "plan_cache_size must be >= 1"
    return None


def check_session_config(cfg: BuildConfig) -> None:
    msg = session_config_violation(cfg)
    if msg is not None:
        raise ValueError(msg)


# ------------------------------------------------------- worker-level
def worker_config_violation(num_workers: int, expr_backend: str,
                            worker_kind: str,
                            socket_launch: Optional[str],
                            socket_addr: Optional[Tuple[str, int]]
                            ) -> Optional[str]:
    """DistributedExecutor's constructor rules (the raw-driver API — the
    Session rules above subsume most of them but standalone callers hit
    these directly). ``socket_launch`` is the *pre-normalization* value:
    the driver defaults it to 'fork' only after these rules pass."""
    if num_workers < 1:
        return "num_workers must be >= 1"
    if expr_backend not in EXPR_BACKENDS:
        return (f"unknown expr_backend {expr_backend!r} "
                f"(expected one of {EXPR_BACKENDS})")
    if worker_kind not in ("thread", "fork", "socket"):
        return (f"unknown worker_kind {worker_kind!r} "
                "(expected 'thread', 'fork', or 'socket')")
    if worker_kind == "fork" and expr_backend == "jax":
        return ("worker_kind='fork' cannot run expr_backend='jax': XLA's "
                "runtime threads do not survive a fork taken after jax "
                "initialized in the parent (forked children would hang in "
                "jit until the 30s SIGTERM) — use worker_kind='thread'")
    if worker_kind != "socket":
        if socket_launch is not None or socket_addr is not None:
            return ("socket_launch/socket_addr only apply to "
                    "worker_kind='socket'")
        return None
    launch = socket_launch or "fork"
    if launch not in SOCKET_LAUNCHES:
        return (f"unknown socket_launch {launch!r} (expected "
                f"one of {SOCKET_LAUNCHES})")
    if launch == "fork" and expr_backend == "jax":
        return ("worker_kind='socket' with socket_launch='fork' cannot "
                "run expr_backend='jax': XLA's runtime threads do not "
                "survive the fork that spawns the connecting workers — "
                "use socket_launch='thread' (in-process workers over "
                "real TCP) or socket_launch='connect' (external worker "
                "processes with their own jax)")
    if launch == "connect" and (socket_addr is None or socket_addr[1] == 0):
        return ("socket_launch='connect' needs an explicit "
                "socket_addr=(host, port) with a nonzero port — "
                "external workers must be told where to dial before "
                "the query runs")
    return None


def check_worker_config(num_workers: int, expr_backend: str,
                        worker_kind: str, socket_launch: Optional[str],
                        socket_addr: Optional[Tuple[str, int]]) -> None:
    msg = worker_config_violation(num_workers, expr_backend, worker_kind,
                                  socket_launch, socket_addr)
    if msg is not None:
        raise ValueError(msg)


# --------------------------------------------------------- plan-level
def capability_diagnostics(prog: TCAPProgram,
                           cfg: Optional[BuildConfig]) -> List[Diagnostic]:
    diags: List[Diagnostic] = []
    if cfg is None:
        return diags
    # PL301: the program must cross the wire pickled. True for external
    # socket workers, and for EVERY service pool launch — the resident
    # pool exists before any query does (no fork image to ride), so
    # QUERY frames always pickle the program.
    if cfg.worker_kind == "socket" and cfg.socket_launch == "connect":
        reason = ("socket_launch='connect' ships the TCAP program to "
                  "external workers by pickling")
        remedy = ("express the query in the lambda DSL, or run "
                  "socket_launch='fork' workers on the driver host")
    elif cfg.backend == "service":
        reason = ("backend='service' ships the TCAP program to resident "
                  "pool workers by pickling (the pool outlives any one "
                  "query, so no launch mode can carry native lambdas in "
                  "a fork image)")
        remedy = "express the query in the lambda DSL"
    else:
        return diags
    for i, op in enumerate(prog.ops):
        if op.op == "APPLY" and op.info.get("type") == "native":
            diags.append(Diagnostic(
                "PL301", "error",
                f"{reason}, and native Python lambdas (make_lambda) "
                f"only exist in-process — stage {op.stage!r} cannot "
                f"cross the wire; {remedy}",
                op_path(i, op)))
    return diags
