"""Pass 1 — schema/dtype dataflow (forward inference over TCAP edges).

Every ``(list, column)`` edge of the program gets a numpy dtype inferred
*without executing the plan*: SCANs resolve their registered record schema
(or the stored set's layout), pipelined stages are probed on zero-row
slices through the very same :func:`~repro.core.relops.stage_eval` the
executors run — so the inferred dtype is the executed dtype by
construction, the property the differential suite pins — and AGG outputs
follow the combiner dtype rules shared with :func:`~repro.core.relops
.sum_acc_dtype` and the group-schema synthesis.

Native lambdas are probed on zero rows too (the same dry-run contract as
``dataset._spec_result``), but columns whose value flows through a native
are marked *tainted*: a native's zero-row dtype is best-effort, so no
error- or warning-severity diagnostic is ever raised on tainted inputs —
the analyzer must never reject a plan that would have executed fine.

Diagnostics raised here:

* **PL103** (error) — ``attAccess`` names a field the inferred structured
  input dtype does not define (untainted inputs only).
* **PL101** (warning) — a float-producing arithmetic stage consumes a
  64-bit integer operand: values above 2^53 lose precision in the float64
  result.
* **PL102** (warning) — ``sum`` accumulates in a small integer dtype
  (i8/i16/i32 and unsigned kin keep their width by the shared accumulator
  rule, so large partitions can overflow silently).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.analysis.diagnostics import Diagnostic, op_path
from repro.core.relops import AggSpec, stage_eval, sum_acc_dtype
from repro.core.tcap import TCAPOp, TCAPProgram
from repro.objectmodel.schema import schema_for

__all__ = ["infer_dtypes", "schema_pass"]

Edge = Tuple[str, str]  # (list name, column name)


def _scan_dtype(op: TCAPOp, store) -> Optional[np.dtype]:
    sch = schema_for(op.info.get("type"))
    if sch is not None:
        return sch.dtype
    if store is not None:
        try:
            return store.get_set(op.info["set"]).dtype
        except KeyError:
            return None
    return None


_PROBE_MEMO: Dict[Tuple, Optional[np.dtype]] = {}
_PROBE_MEMO_CAP = 4096


def _probe_key(op: TCAPOp, ins: Sequence[np.dtype]) -> Optional[Tuple]:
    """A content key for deterministic stage types: same payload + same
    input dtypes -> same output dtype, across programs and sessions.
    Native lambdas (arbitrary user code) never memoize."""
    t = op.info.get("type")
    if t in ("cmp", "bool", "arith"):
        payload: object = op.info["op"]
    elif t == "methodCall":
        payload = (op.info["onType"], op.info["methodName"])
    else:
        return None
    # np.dtype objects hash and compare by content — usable key parts
    return (t, payload, tuple(ins))


def _stage_out_dtype(op: TCAPOp, t: Optional[str],
                     ins: Sequence[Optional[np.dtype]]
                     ) -> Optional[np.dtype]:
    """Output dtype of one pipelined stage. Structurally determined types
    resolve without touching a kernel; only value-semantics stages (arith
    promotion, method calls, natives) fall through to the zero-row probe.
    ``.base`` mirrors the probe's behavior on sub-array record fields: the
    column carries the element dtype (the rows carry the extra axis)."""
    if any(d is None for d in ins):
        return None
    if t == "rename":
        return ins[0]
    if t in ("cmp", "bool"):
        return np.dtype(np.bool_)
    if t == "const":
        return np.asarray(op.info["value"]).dtype
    if t == "attAccess" and ins[0].fields is not None:
        fd = ins[0].fields.get(op.info["attName"])
        return None if fd is None else fd[0].base
    return _probe(op, ins)


def _probe(op: TCAPOp, ins: Sequence[Optional[np.dtype]]
           ) -> Optional[np.dtype]:
    """Zero-row evaluation of one pipelined stage through the shared
    kernel — the dtype the executors will produce, or None when any input
    dtype is unknown or the stage rejects empty input."""
    if any(d is None for d in ins):
        return None
    key = _probe_key(op, ins)
    if key is not None and key in _PROBE_MEMO:
        return _PROBE_MEMO[key]
    try:  # caller holds np.errstate(all="ignore") for the whole pass
        out: Optional[np.dtype] = np.asarray(
            stage_eval(op, [np.zeros(0, d) for d in ins], 0)).dtype
    except Exception:
        out = None
    if key is not None:
        if len(_PROBE_MEMO) >= _PROBE_MEMO_CAP:
            _PROBE_MEMO.clear()
        _PROBE_MEMO[key] = out
    return out


def _agg_dtypes(op: TCAPOp, spec: AggSpec,
                dt: Dict[Edge, Optional[np.dtype]]
                ) -> Dict[str, Optional[np.dtype]]:
    """Output dtypes of one AGG op from the shared combiner rules: sum
    keeps/widen per :func:`sum_acc_dtype`, min/max accumulate float64,
    ``i/j`` finalizers divide (the mean composite)."""
    out: Dict[str, Optional[np.dtype]] = {}
    for kname, kcol in zip(spec.key_names, spec.key_cols(op)):
        out[kname] = dt.get((op.in_list, kcol))
    accs: List[Optional[np.dtype]] = []
    for comb, acol in zip(spec.combiners, spec.acc_cols(op)):
        d = dt.get((op.in_list, acol))
        if d is None:
            accs.append(None)
        elif comb == "sum":
            accs.append(sum_acc_dtype(d))
        else:  # min/max accumulate float64 (relops._scatter_minmax)
            accs.append(np.dtype(np.float64))
    for name, fin in zip(spec.out_names, spec.finalize):
        if "/" in fin:
            i, j = map(int, fin.split("/"))
            a, b = accs[i], accs[j]
            out[name] = (None if a is None or b is None else
                         (np.zeros(0, a) / np.zeros(0, b)).dtype)
        else:
            out[name] = accs[int(fin)]
    return out


def schema_pass(prog: TCAPProgram, store=None
                ) -> Tuple[List[Diagnostic],
                           Dict[Edge, Optional[np.dtype]],
                           Dict[str, Optional[np.dtype]]]:
    """Run the forward dataflow. Returns ``(diagnostics, edge dtypes,
    output schema)`` — the output schema maps the OUTPUT op's projected
    columns to their inferred dtypes (empty when the program has no
    OUTPUT op)."""
    diags: List[Diagnostic] = []
    dt: Dict[Edge, Optional[np.dtype]] = {}
    tainted: Set[Edge] = set()  # value passed through a native lambda
    consty: Set[Edge] = set()   # value derived only from scalar constants

    def copy_through(op: TCAPOp) -> None:
        for c in op.copy_cols:
            dt[(op.out, c)] = dt.get((op.in_list, c))
            if (op.in_list, c) in tainted:
                tainted.add((op.out, c))
            if (op.in_list, c) in consty:
                consty.add((op.out, c))
        for c in op.copy_cols2:
            dt[(op.out, c)] = dt.get((op.in_list2, c))
            if (op.in_list2, c) in tainted:
                tainted.add((op.out, c))
            if (op.in_list2, c) in consty:
                consty.add((op.out, c))

    # one errstate frame for the whole pass: the zero-row probes would
    # otherwise enter/exit it per stage, which dominates analyzer time
    with np.errstate(all="ignore"):
        return _schema_pass_loop(prog, store, diags, dt, tainted, consty,
                                 copy_through)


def _schema_pass_loop(prog, store, diags, dt, tainted, consty,
                      copy_through):
    output: Dict[str, Optional[np.dtype]] = {}
    for i, op in enumerate(prog.ops):
        if op.op == "SCAN":
            dt[(op.out, op.out_cols[0])] = _scan_dtype(op, store)
            continue
        copy_through(op)
        if op.op == "APPLY" and (newc := op.new_cols):
            t = op.info.get("type")
            new = newc[0]
            in_edges = [(op.in_list, c) for c in op.apply_cols]
            ins = [dt.get(e) for e in in_edges]
            in_taint = any(e in tainted for e in in_edges)
            if t == "attAccess" and ins and ins[0] is not None:
                att = op.info["attName"]
                if ins[0].names is not None and att not in ins[0].names:
                    if not in_taint:
                        diags.append(Diagnostic(
                            "PL103", "error",
                            f"unresolved column: field {att!r} is not in "
                            f"the inferred input record dtype "
                            f"(fields: {list(ins[0].names)})",
                            op_path(i, op)))
                    dt[(op.out, new)] = None
                    tainted.add((op.out, new))
                    continue
            out_d = _stage_out_dtype(op, t, ins)
            if t == "native":
                tainted.add((op.out, new))
            elif in_taint:
                tainted.add((op.out, new))
            if t == "const" and np.ndim(op.info.get("value")) == 0:
                consty.add((op.out, new))
            elif (t in ("rename", "cmp", "bool", "arith") and in_edges
                    and all(e in consty for e in in_edges)):
                consty.add((op.out, new))
            # a scalar-constant operand (the literal 1 in `1 - discount`)
            # cannot exceed 2^53 — only data-carrying i64 operands narrow
            if (t == "arith" and out_d is not None and out_d.kind == "f"
                    and not in_taint
                    and any(d is not None and d.kind in "iu"
                            and d.itemsize == 8 and e not in consty
                            for e, d in zip(in_edges, ins))):
                diags.append(Diagnostic(
                    "PL101", "warning",
                    f"dtype narrowing: 64-bit integer operand enters a "
                    f"float-producing '{op.info.get('op')}' stage — values "
                    "above 2^53 lose precision in the float64 result",
                    op_path(i, op)))
            dt[(op.out, new)] = out_d
        elif op.op == "HASH":
            hnew = op.new_cols[0]
            dt[(op.out, hnew)] = np.dtype(np.int64)
            if (op.in_list, op.apply_cols[0]) in tainted:
                tainted.add((op.out, hnew))
        elif op.op == "FLATTEN":
            d0 = dt.get((op.in_list, op.apply_cols[0]))
            # a fixed-width vector column flattens to its base dtype;
            # object sequences (ragged rows) stay unknown
            if d0 is None or d0.kind == "O":
                dt[(op.out, op.out_cols[0])] = None
            else:
                dt[(op.out, op.out_cols[0])] = (
                    d0.subdtype[0] if d0.subdtype else d0)
            if (op.in_list, op.apply_cols[0]) in tainted:
                tainted.add((op.out, op.out_cols[0]))
        elif op.op == "AGG":
            spec = AggSpec.from_op(op)
            acc_taint = any((op.in_list, c) in tainted
                            for c in op.apply_cols)
            for comb, acol in zip(spec.combiners, spec.acc_cols(op)):
                d = dt.get((op.in_list, acol))
                if (comb == "sum" and not acc_taint and d is not None
                        and d.kind in "iu" and d.itemsize < 8):
                    diags.append(Diagnostic(
                        "PL102", "warning",
                        f"accumulator saturation: sum over {d} accumulates "
                        f"in {sum_acc_dtype(d)} — large partitions can "
                        "overflow silently; widen the value to int64 first",
                        op_path(i, op)))
            for kname, kcol in zip(spec.key_names, spec.key_cols(op)):
                kd = dt.get((op.in_list, kcol))
                if (kd is not None and kd.kind == "f"
                        and (op.in_list, kcol) not in tainted):
                    diags.append(Diagnostic(
                        "PL104", "warning",
                        f"float group key {kname!r} ({kd}): NaN != NaN, so "
                        "NaN keys silently fragment into one group per "
                        "row — round or cast the key to an integer/bytes "
                        "dtype if NaNs can occur",
                        op_path(i, op)))
            for name, d in _agg_dtypes(op, spec, dt).items():
                dt[(op.out, name)] = d
                if acc_taint:
                    tainted.add((op.out, name))
        elif op.op == "TOPK":
            # out_cols are ("score", "payload"), carried from apply_cols
            for out_c, in_c in zip(op.out_cols, op.apply_cols):
                dt[(op.out, out_c)] = dt.get((op.in_list, in_c))
                if (op.in_list, in_c) in tainted:
                    tainted.add((op.out, out_c))
        elif op.op == "OUTPUT":
            output = {c: dt.get((op.in_list, c)) for c in op.apply_cols}
        # FILTER and JOIN are pure routing: copy_through covered them

    return diags, dt, output


def infer_dtypes(prog: TCAPProgram, store=None
                 ) -> Dict[Edge, Optional[np.dtype]]:
    """Just the edge dtypes (no diagnostics) — the fusion pass and other
    consumers share the same inference."""
    return schema_pass(prog, store)[1]
