"""``python -m repro.analysis`` — the planlint self-check over the
bundled apps.

Builds each app's characteristic plans on tiny synthetic inputs, runs the
analyzer over every plan (``Dataset.check()``), prints the structured
findings, and exits 1 if any plan carries an error-severity diagnostic.
Eager app paths (the linalg DSL, TPC-H top-k) additionally *execute*,
which routes every plan through the Session's analyzer gate — a gated
plan failing would surface here as the ValueError the gate raises.

``--json`` emits a machine-readable report instead (schema
``repro-planlint/1``: per plan the findings as ``{code, severity,
op_path, message}``, the inferred output schema, and the elided-exchange
op indices); the human progress lines move to stderr and the
exit-1-on-errors contract is unchanged.

CI runs this as the planlint job: the apps must stay analysis-clean at
error severity.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import sys

import numpy as np

from repro.analysis.diagnostics import AnalysisReport


def _report(name: str, rep: AnalysisReport, reports: list) -> None:
    reports.append((name, rep))
    print(f"-- {name}")
    print("   " + rep.format().replace("\n", "\n   "))


def _check_tpch(reports: list) -> None:
    from repro.apps.tpch import q1_pricing_summary, topk_jaccard
    from repro.core.session import Session
    from repro.data.synthetic import denormalized_tpch, tpch_q1_lineitems

    sess = Session(num_partitions=2)
    lines = tpch_q1_lineitems(600, seed=3)
    ds = sess.load("lineitem", lines)
    q1 = q1_pricing_summary(sess.store, ds.set_name, session=sess)
    _report("tpch.q1_pricing_summary", q1.check(), reports)
    q1.collect()  # through the analyzer gate

    # second grouping over Q1's keys: the redundant-exchange elision shape
    from repro.core.aggregates import agg
    chained = (q1.group_by("returnflag", "linestatus")
                 .agg(total=agg.sum("sum_qty")))
    _report("tpch.q1_regroup (elision)", chained.check(), reports)
    chained.collect()

    rng = np.random.default_rng(0)
    _, denorm_lines, _, n_parts = denormalized_tpch(40, seed=0)
    denorm = sess.load("lineitem_denorm", denorm_lines)
    topk_jaccard(sess.store, denorm.set_name, n_parts,
                 rng.integers(0, n_parts, 4), k=3, session=sess)
    print("-- tpch.topk_jaccard: executed through the analyzer gate")


def _check_ml(reports: list) -> None:
    from repro.apps.ml import KMeans, point_schema
    from repro.core.lambdas import make_lambda
    from repro.core.session import Session
    from repro.data.synthetic import points

    x, _labels = points(200, 4, n_clusters=3, seed=1)
    KMeans(k=3, iters=2, num_partitions=2).fit(x)
    print("-- ml.KMeans: executed (2 iterations)")

    # the k-means inner plan, lazily, so planlint sees the program the
    # tool iterates: native key/value projections feeding the aggregation
    sess = Session(num_partitions=2)
    schema = point_schema(x.shape[1])
    C = x[:3].copy()

    def closest(rows):
        return ((rows["x"][:, None] - C[None]) ** 2).sum(-1).argmin(1)

    def with_count(rows):
        return np.concatenate(
            [rows["x"], np.ones((len(rows["x"]), 1))], axis=1)

    step = (sess.load("points", schema.pack(x=x), schema)
                .aggregate(key=lambda a: make_lambda(a, closest, "getClose"),
                           value=lambda a: make_lambda(a, with_count,
                                                       "fromMe")))
    _report("ml.kmeans_step", step.check(), reports)
    step.collect()


def _check_linalg(reports: list) -> None:
    from repro.apps.linalg import (LinAlgSession, _block_mul_fn,
                                   _flat_blocks, matrix_block_schema)
    from repro.core.lambdas import make_lambda, make_lambda_from_member

    rng = np.random.default_rng(2)
    la = LinAlgSession(num_partitions=2, block_size=8)
    la.load("X", rng.normal(size=(24, 8)))
    la.load("y", rng.normal(size=(24, 1)))
    la.run("beta = (X '* X)^-1 %*% (X '* y)")
    print("-- linalg.normal_equations: executed through the analyzer gate")

    # the multiply plan (join on the inner block index + aggregation),
    # lazily, so its report is printed like the others
    schema = matrix_block_schema(la.bs)
    A = la.vars["X"]
    mul = _block_mul_fn(True, "c", la.bs)
    mm = (la.sess.read(A.set_name, schema)
            .join(la.sess.read(A.set_name, schema),
                  on=lambda a, b: (make_lambda_from_member(a, "r")
                                   == make_lambda_from_member(b, "r")),
                  project=lambda a, b: make_lambda([a, b], mul,
                                                   "blockMultiply"))
            .aggregate(key="key", value=_flat_blocks))
    _report("linalg.transpose_multiply", mm.check(), reports)
    mm.collect()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description=__doc__.splitlines()[0])
    ap.add_argument("--json", action="store_true",
                    help="emit a machine-readable JSON report on stdout "
                         "(progress lines go to stderr)")
    args = ap.parse_args(argv)

    reports: list = []
    with contextlib.ExitStack() as stack:
        if args.json:
            # keep stdout pure JSON for tools; the human run log (the
            # checks print as they execute) still lands on stderr
            stack.enter_context(contextlib.redirect_stdout(sys.stderr))
        for check in (_check_tpch, _check_ml, _check_linalg):
            check(reports)
        n_err = sum(len(rep.errors()) for _, rep in reports)
        n_warn = sum(len(rep.warnings()) for _, rep in reports)
        n_info = sum(len(rep.infos()) for _, rep in reports)
        print(f"== planlint: {len(reports)} plans analyzed, {n_err} errors, "
              f"{n_warn} warnings, {n_info} infos ==")
    if args.json:
        doc = {"schema": "repro-planlint/1",
               "plans": [{"name": name, **rep.to_json_dict()}
                         for name, rep in reports],
               "counts": {"error": n_err, "warning": n_warn,
                          "info": n_info}}
        json.dump(doc, sys.stdout, indent=1)
        print()
    if n_err:
        for name, rep in reports:
            for d in rep.errors():
                print(f"ERROR {name}: {d.format()}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
