"""Structured diagnostics — the analyzer's output vocabulary.

Every finding is a :class:`Diagnostic` with a *stable* code (tools and
tests key on them), a severity, a human message, and an ``op_path``
locating the finding in the TCAP program (``op[i]:OP stage``). Codes:

======  ========  =====================================================
code    severity  meaning
======  ========  =====================================================
PL101   warning   dtype narrowing: a 64-bit integer operand enters a
                  float-producing arithmetic stage (values above 2^53
                  lose precision)
PL102   warning   accumulator saturation: ``sum`` over a small integer
                  dtype accumulates in that dtype (i8/i16/i32 sums can
                  overflow silently)
PL103   error     unresolved column: ``attAccess`` of a field the
                  inferred input record dtype does not define
PL104   warning   float group key: grouping on a float-dtype key —
                  ``NaN != NaN``, so NaN keys silently fragment into
                  one group per row
PL201   info      redundant exchange: a planned AGG shuffle whose input
                  is already hash-partitioned on the same key tuple by
                  ``stable_key_hash`` (the optimizer elides it)
PL202   info      co-partitioned join: a hash-partition JOIN side
                  already hash-partitioned on its join key — the side's
                  split+route exchange is the identity permutation (the
                  optimizer elides it)
PL203   info      join algorithm disagreement: the planner's broadcast-
                  vs-hash choice differs from the width-aware byte
                  model (``advise_joins=True`` adopts the modeled
                  choice)
PL301   error     native lambda on a connect-mode plan: the program
                  cannot be pickled to external workers
PL401   info      fusion barrier: an op the stage compiler cannot fuse
                  splits a pipelined run (native lambdas, FLATTEN)
PL402   info      host↔device round-trip: instructions that would
                  return to the host *after* a jitted core within one
                  fused run (jax backend) — the scheduler hoists them
                  ahead of the core
======  ========  =====================================================

Severities: ``error`` diagnostics make :meth:`AnalysisReport.errors`
non-empty — the Session refuses to execute such plans; ``warning`` and
``info`` never block execution.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["Diagnostic", "AnalysisReport", "SEVERITIES", "op_path"]

SEVERITIES = ("error", "warning", "info")


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    code: str       # stable code, e.g. "PL201"
    severity: str   # "error" | "warning" | "info"
    message: str
    op_path: str    # locator within the program, e.g. "op[4]:AGG"

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r} "
                             f"(expected one of {SEVERITIES})")

    def format(self) -> str:
        return f"{self.code} {self.severity:<7} {self.op_path}: " \
               f"{self.message}"


def op_path(i: int, op) -> str:
    """The canonical locator of op ``i``: index, kind, and the stage name
    when the compiler assigned one (APPLY stages carry the lambda kind)."""
    tail = f" {op.stage}" if getattr(op, "stage", "") else ""
    return f"op[{i}]:{op.op}{tail}"


@dataclasses.dataclass
class AnalysisReport:
    """Everything one analyzer run learned about a plan: the diagnostics,
    the forward-inferred output schema (column name -> numpy dtype, None
    where inference gave up), and the AGG op indices whose exchange the
    partitioning pass proved redundant."""

    diagnostics: List[Diagnostic] = dataclasses.field(default_factory=list)
    output_schema: Dict[str, Optional[np.dtype]] = \
        dataclasses.field(default_factory=dict)
    # op indices (AGG and JOIN) whose exchange the plan actually skips
    elided_exchanges: Tuple[int, ...] = ()

    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "error"]

    def warnings(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "warning"]

    def infos(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "info"]

    def format(self) -> str:
        lines = [f"== diagnostics ({len(self.diagnostics)}) =="]
        for d in self.diagnostics:
            lines.append("  " + d.format())
        if not self.diagnostics:
            lines.append("  (clean)")
        if self.output_schema:
            cols = ", ".join(
                f"{c}: {dt if dt is not None else '?'}"
                for c, dt in self.output_schema.items())
            lines.append(f"== inferred output schema: {cols} ==")
        return "\n".join(lines)

    def to_json_dict(self) -> Dict:
        """A machine-readable view (``python -m repro.analysis --json``):
        plain strings/ints only, so it serializes with ``json.dump``."""
        return {
            "findings": [{"code": d.code, "severity": d.severity,
                          "op_path": d.op_path, "message": d.message}
                         for d in self.diagnostics],
            "output_schema": {c: (str(dt) if dt is not None else None)
                              for c, dt in self.output_schema.items()},
            "elided_exchanges": list(self.elided_exchanges),
            "counts": {"error": len(self.errors()),
                       "warning": len(self.warnings()),
                       "info": len(self.infos())},
        }
